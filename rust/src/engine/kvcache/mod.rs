//! Paged KV-cache subsystem: a fixed-size block allocator with refcounted
//! copy-on-write sharing (the vLLM block-manager idea, scaled to this
//! substrate).
//!
//! Before this subsystem the engine charged KV residency as a flat
//! per-slot token count: the G samples of a GRPO group each "held" a
//! private copy of the identical prompt prefix, and a retained partial was
//! evicted whole even when most of its KV was a prefix still resident for
//! live siblings. The block layer replaces that with vLLM-style paging:
//!
//! - [`BlockAllocator`] — a free-list arena of fixed-size blocks
//!   (`block_size` tokens each) with per-block refcounts; the engine's KV
//!   budget is denominated in blocks (`engine.kv_budget_blocks`).
//! - [`PageTable`] — one per sequence (busy or retained slot): the chain
//!   of block refs covering its resident tokens. Appending a token inside
//!   a *shared* partial block first copies it ([`PageTable::append_one`],
//!   the copy-on-write rule), so a shared block is never mutated.
//! - [`PrefixCache`] — the engine's registry of shared prompt prefixes,
//!   keyed by the coordinator's group handle ([`super::WorkItem::prefix`]):
//!   the first admission of a group allocates the prompt blocks once and
//!   registers them; every later sibling attaches the same blocks with a
//!   refcount bump instead of charging fresh residency.
//!
//! # What is (and is not) virtualized
//!
//! The backends in this repo keep *physically* slot-contiguous KV (the AOT
//! decode artifact has no paged-attention kernel, and the mock's "KV" is a
//! script cursor), so prefill still executes per slot. What the block layer
//! virtualizes is the **residency economy**: admission, the KV budget,
//! preemption, retention, and eviction are all charged in refcounted
//! blocks, so a group's shared prefix counts once, a retained partial
//! whose prefix is still live costs near nothing, and more rollouts fit a
//! given budget. [`super::Backend::set_block_table`] mirrors the logical
//! block chain to the backend — the mock enforces the mapping invariants
//! bit-exactly, the PJRT backend keeps a device-side table staged for a
//! future paged decode artifact.
//!
//! Everything here is synchronous, allocation-free on the decode hot path
//! (block chains and the free list are pre-reserved), and exhaustively
//! covered by property-style tests (`allocator.rs`, `pages.rs`).

pub mod allocator;
pub mod pages;

pub use allocator::{BlockAllocator, BlockId};
pub use pages::{PageTable, PrefixCache};

/// Default tokens per KV block (vLLM's default; `engine.kv_block_size`).
pub const DEFAULT_BLOCK_SIZE: usize = 16;

/// Nominal KV elements (keys + values across layers/heads) charged per
/// resident token for **byte accounting**. The substrate has no real
/// weights, so this is a bookkeeping constant (a 2-layer × 4-head × 32-dim
/// toy shape: 2·2·4·32 = 512 halved to keep trace numbers readable) — what
/// matters is that `kv_bytes_peak` scales *linearly* with resident tokens
/// and *per-dtype* with [`KvDtype::bytes_per_elem`], exactly like a real
/// cache would.
pub const KV_ELEMS_PER_TOKEN: usize = 256;

/// Element type KV blocks are stored at (`engine.kv_dtype`). The budget is
/// denominated in **f32-sized blocks** (`kv_budget_blocks` ×
/// [`KvCacheConfig::block_bytes`] at f32), so narrower dtypes fit
/// proportionally more blocks into the same bytes — see
/// [`KvCacheConfig::effective_budget_blocks`].
///
/// Lossiness is modeled deterministically by the backends: `MockBackend`
/// applies a quantize→dequantize round-trip to every logit it emits
/// (f16 via [`f32_to_f16_bits`]/[`f16_bits_to_f32`], int8 via
/// [`int8_roundtrip`] with a per-row scale), `XlaBackend` stages the dtype
/// for the device-side cache.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum KvDtype {
    /// Full-precision 32-bit floats (lossless; the default).
    #[default]
    F32,
    /// IEEE binary16 half precision: 2 bytes/elem, 2× block capacity.
    F16,
    /// Symmetric 8-bit integers with one f32 scale per block: 1 byte/elem,
    /// 4× block capacity.
    Int8,
}

impl KvDtype {
    /// Bytes per stored KV element.
    pub fn bytes_per_elem(self) -> usize {
        match self {
            KvDtype::F32 => 4,
            KvDtype::F16 => 2,
            KvDtype::Int8 => 1,
        }
    }

    /// How many blocks of this dtype fit in the bytes of one f32 block.
    pub fn capacity_multiplier(self) -> usize {
        match self {
            KvDtype::F32 => 1,
            KvDtype::F16 => 2,
            KvDtype::Int8 => 4,
        }
    }

    /// Per-block metadata bytes (the int8 dequantization scale).
    pub fn block_scale_bytes(self) -> usize {
        match self {
            KvDtype::Int8 => 4,
            _ => 0,
        }
    }

    /// Canonical config/trace name: `"f32"` / `"f16"` / `"int8"`.
    pub fn name(self) -> &'static str {
        match self {
            KvDtype::F32 => "f32",
            KvDtype::F16 => "f16",
            KvDtype::Int8 => "int8",
        }
    }

    /// Parse a config value; accepts the canonical names plus the common
    /// aliases `fp16`/`half` and `i8`. `None` for anything else.
    pub fn parse(s: &str) -> Option<KvDtype> {
        match s.trim().to_ascii_lowercase().as_str() {
            "f32" | "fp32" | "float32" => Some(KvDtype::F32),
            "f16" | "fp16" | "half" => Some(KvDtype::F16),
            "int8" | "i8" => Some(KvDtype::Int8),
            _ => None,
        }
    }
}

/// f32 → IEEE binary16 bit pattern, round-to-nearest-even (the hardware
/// conversion rule). Handles normals, subnormals, overflow→inf, inf, NaN
/// (quietized, payload truncated). No `half` crate — the repo models the
/// conversion itself so the quantization is auditable.
pub fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xff) as i32;
    let mant = bits & 0x007f_ffff;
    if exp == 0xff {
        // Inf / NaN: keep NaN-ness (set a mantissa bit so it stays NaN).
        return sign | 0x7c00 | if mant != 0 { 0x0200 } else { 0 };
    }
    // Re-bias 127 → 15.
    let e16 = exp - 127 + 15;
    if e16 >= 0x1f {
        return sign | 0x7c00; // overflow → ±inf
    }
    if e16 <= 0 {
        // Subnormal (or underflow to zero): shift the implicit-1 mantissa
        // right, round to nearest even on the dropped bits.
        if e16 < -10 {
            return sign; // underflows past the smallest subnormal → ±0
        }
        let m = mant | 0x0080_0000; // implicit leading 1
        let shift = (14 - e16) as u32; // bits dropped from the 24-bit mantissa
        let halfway = 1u32 << (shift - 1);
        let rest = m & ((1u32 << shift) - 1);
        let mut out = (m >> shift) as u16;
        if rest > halfway || (rest == halfway && (out & 1) != 0) {
            out += 1; // may carry into the exponent — that is correct
        }
        return sign | out;
    }
    // Normal: round 23-bit mantissa to 10 bits, nearest even.
    let rest = mant & 0x1fff;
    let mut out = ((e16 as u32) << 10 | (mant >> 13)) as u16;
    if rest > 0x1000 || (rest == 0x1000 && (out & 1) != 0) {
        out += 1; // mantissa carry rolls into the exponent correctly
    }
    sign | out
}

/// IEEE binary16 bit pattern → f32 (exact — every f16 value is an f32).
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h as u32) & 0x8000) << 16;
    let exp = ((h >> 10) & 0x1f) as u32;
    let mant = (h & 0x03ff) as u32;
    let bits = if exp == 0x1f {
        sign | 0x7f80_0000 | (mant << 13) // inf / NaN
    } else if exp == 0 {
        if mant == 0 {
            sign // ±0
        } else {
            // Subnormal: normalize into an f32 normal. With the mantissa's
            // top set bit at position 10-lead, the value is
            // 1.f × 2^(-14-lead) → f32 biased exponent 113-lead; shifting
            // by `lead` parks the leading 1 at bit 10, the mask drops it.
            let lead = mant.leading_zeros() - 21; // zeros above bit 10
            let m = (mant << lead) & 0x03ff;
            let e = 113 - lead;
            sign | (e << 23) | (m << 13)
        }
    } else {
        sign | ((exp + 127 - 15) << 23) | (mant << 13)
    };
    f32::from_bits(bits)
}

/// Deterministic symmetric int8 quantize→dequantize round-trip with the
/// given scale: `round(clamp(v/scale)) * scale`, saturating at ±127. A
/// non-positive or non-finite scale degrades to 1.0 (the all-zero row).
pub fn int8_roundtrip(v: f32, scale: f32) -> f32 {
    let s = if scale.is_finite() && scale > 0.0 { scale } else { 1.0 };
    ((v / s).round().clamp(-127.0, 127.0)) * s
}

/// The symmetric per-row int8 scale: `max|v| / 127` (1.0 for an all-zero
/// or non-finite row so the round-trip stays well-defined).
pub fn int8_row_scale(row: &[f32]) -> f32 {
    let mut amax = 0.0f32;
    for &v in row {
        if v.is_finite() {
            amax = amax.max(v.abs());
        }
    }
    if amax > 0.0 {
        amax / 127.0
    } else {
        1.0
    }
}

/// Engine-side KV-cache configuration: how residency is paged, budgeted
/// and shared. Assembled from [`crate::config::EngineConfig`] via
/// `kv_cache_config()`; the token-denominated legacy budget converts with
/// [`KvCacheConfig::from_token_budget`].
#[derive(Clone, Copy, Debug)]
pub struct KvCacheConfig {
    /// Tokens per block (must be ≥ 1).
    pub block_size: usize,
    /// KV budget in blocks (0 = unlimited). Enforced softly, like the old
    /// token budget: caches (prefix registry entries, retained slots) are
    /// evicted first, then live slots are preempted LIFO; admission of
    /// fresh work backpressures cleanly instead of thrashing.
    pub budget_blocks: usize,
    /// Honor [`super::WorkItem::prefix`] handles: share a group's prompt
    /// blocks across its samples via the [`PrefixCache`].
    pub prefix_sharing: bool,
    /// Element type blocks are stored at (`engine.kv_dtype`). The budget
    /// stays denominated in f32-sized blocks; see
    /// [`KvCacheConfig::effective_budget_blocks`].
    pub dtype: KvDtype,
}

impl Default for KvCacheConfig {
    fn default() -> Self {
        KvCacheConfig {
            block_size: DEFAULT_BLOCK_SIZE,
            budget_blocks: 0,
            prefix_sharing: true,
            dtype: KvDtype::F32,
        }
    }
}

impl KvCacheConfig {
    /// Unlimited budget, default block size, sharing on.
    pub fn unlimited() -> Self {
        Self::default()
    }

    /// Conversion from a token-denominated budget (the removed
    /// `engine.kv_budget_tokens` knob's semantics, kept for call sites
    /// that state budgets in tokens): ceil(tokens / block_size) blocks, so
    /// a token budget never becomes *tighter* than it was.
    pub fn from_token_budget(tokens: usize, block_size: usize) -> Self {
        let bs = block_size.max(1);
        KvCacheConfig {
            block_size: bs,
            budget_blocks: tokens.div_ceil(bs), // 0 stays 0 (unlimited)
            prefix_sharing: true,
            dtype: KvDtype::F32,
        }
    }

    /// The budget expressed back in tokens (0 = unlimited) — the "both
    /// forms" half of the Table-3 config echo.
    pub fn budget_tokens(&self) -> usize {
        self.budget_blocks * self.block_size
    }

    /// The block budget the engine actually enforces: `budget_blocks` is
    /// denominated in f32-sized blocks (`budget_blocks × block_bytes(f32)`
    /// real bytes), so f16 doubles and int8 quadruples the number of
    /// resident blocks that fit. 0 (unlimited) stays 0.
    pub fn effective_budget_blocks(&self) -> usize {
        self.budget_blocks * self.dtype.capacity_multiplier()
    }

    /// Real bytes one resident block occupies at this config's dtype:
    /// `block_size × KV_ELEMS_PER_TOKEN × bytes_per_elem` plus the
    /// per-block scale metadata (int8 only).
    pub fn block_bytes(&self) -> usize {
        self.block_size * KV_ELEMS_PER_TOKEN * self.dtype.bytes_per_elem()
            + self.dtype.block_scale_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn token_budget_converts_with_ceil() {
        let kv = KvCacheConfig::from_token_budget(30, 16);
        assert_eq!(kv.budget_blocks, 2);
        assert_eq!(kv.budget_tokens(), 32);
        let kv = KvCacheConfig::from_token_budget(32, 16);
        assert_eq!(kv.budget_blocks, 2);
        let kv = KvCacheConfig::from_token_budget(0, 16);
        assert_eq!(kv.budget_blocks, 0, "0 stays unlimited");
        assert_eq!(kv.budget_tokens(), 0);
    }

    #[test]
    fn defaults_share_with_unlimited_budget() {
        let kv = KvCacheConfig::default();
        assert_eq!(kv.block_size, DEFAULT_BLOCK_SIZE);
        assert_eq!(kv.budget_blocks, 0);
        assert!(kv.prefix_sharing);
        assert_eq!(kv.dtype, KvDtype::F32);
    }

    #[test]
    fn kv_dtype_names_parse_round_trip() {
        for d in [KvDtype::F32, KvDtype::F16, KvDtype::Int8] {
            assert_eq!(KvDtype::parse(d.name()), Some(d));
        }
        assert_eq!(KvDtype::parse("fp16"), Some(KvDtype::F16));
        assert_eq!(KvDtype::parse("half"), Some(KvDtype::F16));
        assert_eq!(KvDtype::parse("i8"), Some(KvDtype::Int8));
        assert_eq!(KvDtype::parse(" F32 "), Some(KvDtype::F32));
        assert_eq!(KvDtype::parse("bf16"), None);
    }

    #[test]
    fn narrower_dtypes_multiply_effective_blocks_not_raw_budget() {
        let mut kv = KvCacheConfig { budget_blocks: 10, ..KvCacheConfig::default() };
        assert_eq!(kv.effective_budget_blocks(), 10);
        kv.dtype = KvDtype::F16;
        assert_eq!(kv.effective_budget_blocks(), 20);
        kv.dtype = KvDtype::Int8;
        assert_eq!(kv.effective_budget_blocks(), 40);
        assert_eq!(kv.budget_blocks, 10, "raw budget stays f32-denominated");
        kv.budget_blocks = 0;
        assert_eq!(kv.effective_budget_blocks(), 0, "unlimited stays unlimited");
    }

    #[test]
    fn block_bytes_scale_with_dtype_plus_int8_scale_overhead() {
        let mut kv = KvCacheConfig::default(); // block_size 16
        let f32_bytes = 16 * KV_ELEMS_PER_TOKEN * 4;
        assert_eq!(kv.block_bytes(), f32_bytes);
        kv.dtype = KvDtype::F16;
        assert_eq!(kv.block_bytes(), f32_bytes / 2);
        kv.dtype = KvDtype::Int8;
        assert_eq!(kv.block_bytes(), f32_bytes / 4 + 4);
    }

    #[test]
    fn f16_round_trip_is_exact_on_representable_values() {
        // The mock's logit alphabet is exactly representable in binary16 —
        // this is what makes the f16 KV goldens bit-identical to f32.
        for v in [-20.0f32, 10.0, 6.0, 0.0, -0.0, 1.0, -1.5, 0.25, 65504.0] {
            let rt = f16_bits_to_f32(f32_to_f16_bits(v));
            assert_eq!(rt.to_bits(), v.to_bits(), "{v} not exact through f16");
        }
    }

    #[test]
    fn f16_conversion_rounds_overflows_and_subnormals_correctly() {
        // Round-to-nearest-even at the 10-bit mantissa boundary.
        assert_eq!(f16_bits_to_f32(f32_to_f16_bits(1.0 + 1.0 / 2048.0)), 1.0);
        assert_eq!(
            f16_bits_to_f32(f32_to_f16_bits(1.0 + 3.0 / 2048.0)),
            1.0 + 2.0 / 1024.0
        );
        // Overflow saturates to inf, sign preserved.
        assert_eq!(f32_to_f16_bits(1e9), 0x7c00);
        assert_eq!(f32_to_f16_bits(-1e9), 0xfc00);
        assert!(f16_bits_to_f32(f32_to_f16_bits(f32::NAN)).is_nan());
        assert_eq!(f16_bits_to_f32(f32_to_f16_bits(f32::INFINITY)), f32::INFINITY);
        // Smallest f16 subnormal survives the round trip; half of it
        // rounds to even (zero).
        let min_sub = 2.0f32.powi(-24);
        assert_eq!(f16_bits_to_f32(f32_to_f16_bits(min_sub)), min_sub);
        assert_eq!(f16_bits_to_f32(f32_to_f16_bits(min_sub / 2.0)), 0.0);
        assert_eq!(f16_bits_to_f32(f32_to_f16_bits(min_sub * 1.5)), min_sub * 2.0);
        // Largest subnormal and the normal boundary.
        let min_norm = 2.0f32.powi(-14);
        assert_eq!(f16_bits_to_f32(f32_to_f16_bits(min_norm)), min_norm);
        assert_eq!(f16_bits_to_f32(f32_to_f16_bits(min_norm - min_sub)), min_norm - min_sub);
    }

    #[test]
    fn f16_round_trip_error_is_bounded_by_half_ulp() {
        let mut g = crate::util::Rng::new(99);
        for _ in 0..2000 {
            let v = (g.next_f64() * 40.0 - 20.0) as f32;
            let rt = f16_bits_to_f32(f32_to_f16_bits(v));
            // binary16 has 11 significand bits → rel. error ≤ 2^-11.
            assert!(
                (rt - v).abs() <= v.abs() * (1.0 / 2048.0) + 1e-7,
                "{v} → {rt}"
            );
        }
    }

    #[test]
    fn int8_roundtrip_is_deterministic_and_saturating() {
        let row = [-20.0f32, 10.0, 6.0, 0.0];
        let s = int8_row_scale(&row);
        assert!((s - 20.0 / 127.0).abs() < 1e-7);
        for &v in &row {
            let q = int8_roundtrip(v, s);
            assert_eq!(q.to_bits(), int8_roundtrip(v, s).to_bits(), "deterministic");
            assert!((q - v).abs() <= s / 2.0 + 1e-7, "{v} → {q} (scale {s})");
        }
        // max|v| maps to exactly ±127 steps.
        assert_eq!(int8_roundtrip(-20.0, s), -127.0 * s);
        // Values beyond the scale range saturate instead of wrapping.
        assert_eq!(int8_roundtrip(1e6, s), 127.0 * s);
        // Degenerate rows fall back to scale 1.0.
        assert_eq!(int8_row_scale(&[0.0, 0.0]), 1.0);
        assert_eq!(int8_roundtrip(0.4, 0.0), 0.0);
    }
}
