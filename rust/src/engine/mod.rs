//! The inference-engine substrate (vLLM v0.8.4 stand-in, DESIGN.md table):
//! slot-based continuous batching over the AOT decode artifact, a paged
//! KV-cache block manager ([`kvcache`]: refcounted blocks, blocks-
//! denominated budget, copy-on-write prompt-prefix sharing across GRPO
//! groups) with preemption + re-prefill (the paper's "recomputation
//! overhead"), KV retention for affinity-resumed partials (the fast path
//! that skips that recomputation — see `engine::Engine`'s module docs),
//! temperature/top-p/top-k sampling, and per-step utilization traces
//! (Fig. 1b).
//!
//! Engines run on OS threads and are driven by the coordinator through
//! mpsc channels; the decode step has *constant* cost regardless of how
//! many slots are active — idle slots burn compute exactly like the idle
//! GPUs in the paper's Fig. 1.

pub mod backend;
pub mod engine;
pub mod kvcache;
pub mod pool;
pub mod sampler;
pub mod simd;

pub use backend::{is_transient, Backend, BackendError, MockBackend, XlaBackend};
pub use engine::{
    Engine, EngineCmd, EngineEvent, EngineOpts, FinishReason, StepTrace, WorkItem, WorkResult,
};
pub use kvcache::{
    BlockAllocator, BlockId, KvCacheConfig, KvDtype, PageTable, PrefixCache, DEFAULT_BLOCK_SIZE,
    KV_ELEMS_PER_TOKEN,
};
pub use pool::{EnginePool, PoolApi, SupervisorOpts};
pub use sampler::{
    sample_token, sample_token_dispatched, sample_token_with, SamplerScratch, SamplingParams,
};
pub use simd::SamplerDispatch;
