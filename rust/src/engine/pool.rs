//! Threaded engine pool: each engine runs on its own OS thread with a
//! thread-confined PJRT device (see runtime/mod.rs thread model), driven by
//! `EngineCmd` channels; all engines share one `EngineEvent` channel back to
//! the coordinator.

use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::thread::JoinHandle;

use anyhow::Result;

use super::backend::{is_transient, Backend};
use super::engine::{Engine, EngineCmd, EngineEvent, EngineOpts};
use super::kvcache::{KvCacheConfig, DEFAULT_BLOCK_SIZE};

/// Supervision policy for the engine run loop: how hard to retry a step
/// that failed with a [`super::BackendError::Transient`] before the engine
/// declares itself failed (`EngineEvent::EngineFailed`). Fatal errors and
/// panics skip the retry budget entirely.
#[derive(Clone, Copy, Debug)]
pub struct SupervisorOpts {
    /// Transient retries per failing step (`engine.max_retries`).
    pub max_retries: usize,
    /// Base backoff between transient retries in milliseconds, doubling
    /// per attempt (`engine.retry_backoff_ms`). 0 = no sleep.
    pub retry_backoff_ms: u64,
}

impl Default for SupervisorOpts {
    fn default() -> Self {
        SupervisorOpts { max_retries: 3, retry_backoff_ms: 10 }
    }
}

/// The poll/command surface every engine-fleet handle exposes — the
/// in-process [`EnginePool`] and the transport-spanning
/// [`RouterPool`](crate::router::RouterPool) implement it identically, so
/// the coordinator (and any other driver) can be written once, generic
/// over where the engines actually run. Inherent methods remain on both
/// types; the trait simply names the shared surface instead of relying on
/// the two poll APIs staying duplicated by convention.
pub trait PoolApi {
    /// Number of engines (replicas) behind this handle.
    fn engines(&self) -> usize;
    /// Total decode slots across the fleet.
    fn total_slots(&self) -> usize;
    /// Send one command to one engine (global id). Delivery to a dead
    /// engine is silently dropped — its absence surfaces through events.
    fn send(&self, engine: usize, cmd: EngineCmd);
    /// Non-blocking poll; collapses "empty" and "disconnected" into `None`.
    fn try_next(&self) -> Option<EngineEvent>;
    /// Non-blocking poll distinguishing "nothing queued yet" (`Ok(None)`)
    /// from "every engine gone" (`Err(Disconnected)`).
    fn try_next_checked(
        &self,
    ) -> Result<Option<EngineEvent>, std::sync::mpsc::RecvTimeoutError>;
    /// Bounded wait: the next event, blocking no later than `deadline`.
    fn next_before(
        &self,
        deadline: std::time::Instant,
    ) -> Result<EngineEvent, std::sync::mpsc::RecvTimeoutError>;
    /// Weight sync to every engine; `invalidate_retained` drops retained
    /// KV first (the default policy).
    fn broadcast_params(
        &self,
        version: u64,
        params: std::sync::Arc<Vec<f32>>,
        invalidate_retained: bool,
    );
    /// Early-terminate every engine; with `retain`, flushed slots keep
    /// their KV resident for affinity resume.
    fn stop_generation_all_with(&self, retain: bool);
    /// Orderly teardown (joins engine threads / link threads).
    fn shutdown(self)
    where
        Self: Sized;
}

impl PoolApi for EnginePool {
    fn engines(&self) -> usize {
        EnginePool::engines(self)
    }
    fn total_slots(&self) -> usize {
        EnginePool::total_slots(self)
    }
    fn send(&self, engine: usize, cmd: EngineCmd) {
        EnginePool::send(self, engine, cmd)
    }
    fn try_next(&self) -> Option<EngineEvent> {
        EnginePool::try_next(self)
    }
    fn try_next_checked(
        &self,
    ) -> Result<Option<EngineEvent>, std::sync::mpsc::RecvTimeoutError> {
        EnginePool::try_next_checked(self)
    }
    fn next_before(
        &self,
        deadline: std::time::Instant,
    ) -> Result<EngineEvent, std::sync::mpsc::RecvTimeoutError> {
        EnginePool::next_before(self, deadline)
    }
    fn broadcast_params(
        &self,
        version: u64,
        params: std::sync::Arc<Vec<f32>>,
        invalidate_retained: bool,
    ) {
        EnginePool::broadcast_params(self, version, params, invalidate_retained)
    }
    fn stop_generation_all_with(&self, retain: bool) {
        EnginePool::stop_generation_all_with(self, retain)
    }
    fn shutdown(self) {
        EnginePool::shutdown(self)
    }
}

/// Handle to a set of engine threads: per-engine command channels in, one
/// shared event channel out.
pub struct EnginePool {
    senders: Vec<Sender<EngineCmd>>,
    /// Shared event stream from every engine (prefer the `try_next` /
    /// `next_before` polls over raw `recv`).
    pub events: Receiver<EngineEvent>,
    handles: Vec<JoinHandle<()>>,
    /// Decode slots per engine (capacity accounting).
    pub slots_per_engine: usize,
}

impl EnginePool {
    /// Back-compat spawn: a TOKEN-denominated KV budget (0 = unlimited),
    /// converted to blocks of [`DEFAULT_BLOCK_SIZE`]. New call sites
    /// should pass an explicit [`KvCacheConfig`] via
    /// [`EnginePool::spawn_kv`] (e.g. `cfg.engine.kv_cache_config()`).
    pub fn spawn<B, F>(
        n: usize,
        slots_per_engine: usize,
        kv_budget_tokens: usize,
        seed: u64,
        factory: F,
    ) -> Result<EnginePool>
    where
        B: Backend + 'static,
        F: Fn(usize) -> Box<dyn FnOnce() -> Result<B> + Send> + Sync,
    {
        Self::spawn_kv(
            n,
            slots_per_engine,
            KvCacheConfig::from_token_budget(kv_budget_tokens, DEFAULT_BLOCK_SIZE),
            seed,
            factory,
        )
    }

    /// Spawn `n` engines with an explicit paged-KV configuration (legacy
    /// slot admission; use [`EnginePool::spawn_opts`] for the
    /// continuous-batching scheduler).
    pub fn spawn_kv<B, F>(
        n: usize,
        slots_per_engine: usize,
        kv: KvCacheConfig,
        seed: u64,
        factory: F,
    ) -> Result<EnginePool>
    where
        B: Backend + 'static,
        F: Fn(usize) -> Box<dyn FnOnce() -> Result<B> + Send> + Sync,
    {
        Self::spawn_opts(
            n,
            slots_per_engine,
            EngineOpts { kv, step_token_budget: 0 },
            seed,
            factory,
        )
    }

    /// Spawn `n` engines with full scheduling options (paged-KV config +
    /// continuous-batching step-token budget — see
    /// `EngineConfig::engine_opts`) and the default supervision policy.
    /// `factory(engine_id)` runs INSIDE each engine thread and builds its
    /// (thread-confined) backend.
    pub fn spawn_opts<B, F>(
        n: usize,
        slots_per_engine: usize,
        opts: EngineOpts,
        seed: u64,
        factory: F,
    ) -> Result<EnginePool>
    where
        B: Backend + 'static,
        F: Fn(usize) -> Box<dyn FnOnce() -> Result<B> + Send> + Sync,
    {
        Self::spawn_supervised(n, slots_per_engine, opts, SupervisorOpts::default(), seed, factory)
    }

    /// Spawn `n` engines with an explicit supervision policy
    /// (`EngineConfig::supervisor_opts`): transient backend errors retry in
    /// place with bounded exponential backoff; fatal errors, exhausted
    /// retries, panics, and backend-init failures convert the engine into
    /// an `EngineEvent::EngineFailed` instead of a silent thread death.
    pub fn spawn_supervised<B, F>(
        n: usize,
        slots_per_engine: usize,
        opts: EngineOpts,
        sup: SupervisorOpts,
        seed: u64,
        factory: F,
    ) -> Result<EnginePool>
    where
        B: Backend + 'static,
        F: Fn(usize) -> Box<dyn FnOnce() -> Result<B> + Send> + Sync,
    {
        Self::spawn_supervised_at(0, n, slots_per_engine, opts, sup, seed, factory)
    }

    /// [`EnginePool::spawn_supervised`] with an explicit engine-id base:
    /// the `n` engines get ids `id_base .. id_base + n`, and every event
    /// they emit carries those ids. The engine-host process mode uses this
    /// so a host's engines are born with their POOL-GLOBAL replica ids —
    /// events cross the wire untranslated, and the per-engine RNG stream
    /// (`seed ^ id`-derived) matches what a single local pool of the same
    /// total size would produce. `factory` still receives the global id.
    pub fn spawn_supervised_at<B, F>(
        id_base: usize,
        n: usize,
        slots_per_engine: usize,
        opts: EngineOpts,
        sup: SupervisorOpts,
        seed: u64,
        factory: F,
    ) -> Result<EnginePool>
    where
        B: Backend + 'static,
        F: Fn(usize) -> Box<dyn FnOnce() -> Result<B> + Send> + Sync,
    {
        let (ev_tx, ev_rx) = channel::<EngineEvent>();
        let mut senders = Vec::new();
        let mut handles = Vec::new();
        for id in id_base..id_base + n {
            let (cmd_tx, cmd_rx) = channel::<EngineCmd>();
            let tx = ev_tx.clone();
            let build = factory(id);
            let handle = std::thread::Builder::new()
                .name(format!("engine-{id}"))
                .spawn(move || {
                    let backend = match build() {
                        Ok(b) => b,
                        Err(e) => {
                            // An engine that never came up is a failed
                            // engine with nothing in flight — same recovery
                            // path as a mid-run death.
                            eprintln!("engine-{id}: backend init failed: {e:#}");
                            let _ = tx.send(EngineEvent::EngineFailed {
                                engine: id,
                                error: format!("backend init failed: {e:#}"),
                                inflight: Vec::new(),
                                retained: Vec::new(),
                            });
                            let _ = tx.send(EngineEvent::ShutDown { engine: id });
                            return;
                        }
                    };
                    let engine = Engine::with_opts(id, backend, opts, seed);
                    run_loop(engine, cmd_rx, tx, sup);
                })?;
            senders.push(cmd_tx);
            handles.push(handle);
        }
        Ok(EnginePool { senders, events: ev_rx, handles, slots_per_engine })
    }

    /// Number of engine threads.
    pub fn engines(&self) -> usize {
        self.senders.len()
    }

    /// Detach the event receiver, replacing it with a permanently-empty
    /// stand-in. The engine-host socket loop uses this to pump events from
    /// a dedicated thread while the pool (command senders) stays on the
    /// read thread; after the swap `try_next`/`next_before` on the pool
    /// itself report Disconnected.
    pub fn take_events(&mut self) -> Receiver<EngineEvent> {
        let (_dead_tx, dead_rx) = channel::<EngineEvent>();
        std::mem::replace(&mut self.events, dead_rx)
    }

    /// Non-blocking poll: the next queued event, if one is already
    /// waiting. The stage driver's fast path — a pipelined caller drains
    /// whatever accumulated during trainer work without ever parking.
    /// Collapses "empty" and "disconnected" into `None`; callers that must
    /// tell those apart use [`EnginePool::try_next_checked`].
    pub fn try_next(&self) -> Option<EngineEvent> {
        self.events.try_recv().ok()
    }

    /// Non-blocking poll that distinguishes "nothing queued yet"
    /// (`Ok(None)`) from "every engine thread is gone"
    /// (`Err(Disconnected)`) — the coordinator routes the latter into its
    /// degraded-mode failure path instead of spinning or panicking.
    pub fn try_next_checked(
        &self,
    ) -> Result<Option<EngineEvent>, std::sync::mpsc::RecvTimeoutError> {
        match self.events.try_recv() {
            Ok(e) => Ok(Some(e)),
            Err(TryRecvError::Empty) => Ok(None),
            Err(TryRecvError::Disconnected) => {
                Err(std::sync::mpsc::RecvTimeoutError::Disconnected)
            }
        }
    }

    /// Bounded wait: the next event, blocking no later than `deadline`
    /// (past deadlines degrade to a non-blocking poll). `Disconnected`
    /// means every engine thread is gone — callers should bail, not spin.
    pub fn next_before(
        &self,
        deadline: std::time::Instant,
    ) -> Result<EngineEvent, std::sync::mpsc::RecvTimeoutError> {
        let now = std::time::Instant::now();
        if deadline <= now {
            return self.events.try_recv().map_err(|e| match e {
                TryRecvError::Empty => std::sync::mpsc::RecvTimeoutError::Timeout,
                TryRecvError::Disconnected => std::sync::mpsc::RecvTimeoutError::Disconnected,
            });
        }
        self.events.recv_timeout(deadline - now)
    }

    /// Total decode slots across the pool.
    pub fn total_slots(&self) -> usize {
        self.engines() * self.slots_per_engine
    }

    /// Send one command to one engine.
    pub fn send(&self, engine: usize, cmd: EngineCmd) {
        // A dead engine thread surfaces via missing Flushed/Done events;
        // send errors here are secondary.
        let _ = self.senders[engine].send(cmd);
    }

    /// Weight sync to every engine. `invalidate_retained` drops all
    /// retained KV first (the default policy: retained prefixes are stale
    /// w.r.t. the new params); pass `false` only when the coordinator has
    /// opted into cross-sync retention (`rollout.retain_kv_across_sync`).
    pub fn broadcast_params(
        &self,
        version: u64,
        params: std::sync::Arc<Vec<f32>>,
        invalidate_retained: bool,
    ) {
        for s in &self.senders {
            let _ = s.send(EngineCmd::SetParams {
                version,
                params: params.clone(),
                invalidate_retained,
            });
        }
    }

    /// Early-terminate every engine without retaining KV (the replay-only
    /// baseline path; the frozen reference coordinator uses this).
    pub fn stop_generation_all(&self) {
        self.stop_generation_all_with(false);
    }

    /// Early-terminate every engine; with `retain`, flushed slots keep
    /// their KV resident for affinity resume (see `Engine::stop_generation`).
    pub fn stop_generation_all_with(&self, retain: bool) {
        for s in &self.senders {
            let _ = s.send(EngineCmd::StopGeneration { retain });
        }
    }

    /// Join every engine thread after sending Shutdown.
    pub fn shutdown(self) {
        for s in &self.senders {
            let _ = s.send(EngineCmd::Shutdown);
        }
        for h in self.handles {
            let _ = h.join();
        }
    }
}

/// Engine thread main loop: drain commands, step while there is work,
/// block on the channel when idle. Supervised: backend errors and panics
/// anywhere in the step or command path become a single
/// [`EngineEvent::EngineFailed`] (after the transient-retry budget is
/// spent) followed by `ShutDown`, never a silent thread death.
fn run_loop<B: Backend>(
    mut engine: Engine<B>,
    cmd_rx: Receiver<EngineCmd>,
    ev_tx: Sender<EngineEvent>,
    sup: SupervisorOpts,
) {
    let id = engine.id;
    let mut events: Vec<EngineEvent> = Vec::new();
    'outer: loop {
        // 1. Drain all queued commands without blocking.
        loop {
            match cmd_rx.try_recv() {
                Ok(cmd) => match supervised_cmd(&mut engine, cmd, &mut events) {
                    Ok(true) => break 'outer,
                    Ok(false) => {}
                    Err(msg) => {
                        flush(&ev_tx, &mut events);
                        report_failure(&engine, &ev_tx, msg);
                        break 'outer;
                    }
                },
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => break 'outer,
            }
        }
        flush(&ev_tx, &mut events);

        // 2. Idle: block until the next command arrives.
        if !engine.has_work() {
            match cmd_rx.recv() {
                Ok(cmd) => {
                    match supervised_cmd(&mut engine, cmd, &mut events) {
                        Ok(true) => break 'outer,
                        Ok(false) => {}
                        Err(msg) => {
                            flush(&ev_tx, &mut events);
                            report_failure(&engine, &ev_tx, msg);
                            break 'outer;
                        }
                    }
                    flush(&ev_tx, &mut events);
                    continue;
                }
                Err(_) => break 'outer,
            }
        }

        // 3. One decode step, under supervision.
        if let Err(msg) = supervised_step(&mut engine, &ev_tx, &mut events, sup) {
            flush(&ev_tx, &mut events);
            report_failure(&engine, &ev_tx, msg);
            break 'outer;
        }
        flush(&ev_tx, &mut events);
    }
    let _ = ev_tx.send(EngineEvent::ShutDown { engine: id });
}

/// One engine step under the supervision policy. Transient backend errors
/// ([`super::BackendError::Transient`] anywhere in the chain) retry the
/// whole step in place with bounded exponential backoff — `Engine::step`
/// surfaces backend errors BEFORE any per-slot state advances, so a retry
/// re-runs the exact same step bit-for-bit. Fatal errors, exhausted
/// retries, and panics return the failure message for `report_failure`.
fn supervised_step<B: Backend>(
    engine: &mut Engine<B>,
    ev_tx: &Sender<EngineEvent>,
    events: &mut Vec<EngineEvent>,
    sup: SupervisorOpts,
) -> Result<(), String> {
    let id = engine.id;
    let mut attempt = 0usize;
    loop {
        let step =
            std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| engine.step(events)));
        match step {
            Ok(Ok(())) => return Ok(()),
            Ok(Err(e)) if is_transient(&e) && attempt < sup.max_retries => {
                attempt += 1;
                engine.retries += 1;
                // Events from the failed attempt are real (vacated slots,
                // completed admissions) — ship them before re-running so
                // the retry starts from a clean buffer.
                flush(ev_tx, events);
                let backoff =
                    sup.retry_backoff_ms.saturating_mul(1u64 << (attempt - 1).min(16));
                eprintln!(
                    "engine-{id}: transient step error (attempt {attempt}/{}), \
                     retrying in {backoff} ms: {e:#}",
                    sup.max_retries
                );
                if backoff > 0 {
                    std::thread::sleep(std::time::Duration::from_millis(backoff));
                }
            }
            Ok(Err(e)) => return Err(format!("step failed: {e:#}")),
            Err(payload) => {
                return Err(format!("step panicked: {}", panic_message(payload.as_ref())))
            }
        }
    }
}

/// `handle_cmd` under `catch_unwind`: a panic in the command path (weight
/// sync, flush, retained-KV release) is an engine failure like any other.
/// `Ok(true)` means Shutdown was requested.
fn supervised_cmd<B: Backend>(
    engine: &mut Engine<B>,
    cmd: EngineCmd,
    events: &mut Vec<EngineEvent>,
) -> Result<bool, String> {
    std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| handle_cmd(engine, cmd, events)))
        .map_err(|p| format!("command handler panicked: {}", panic_message(p.as_ref())))
}

/// Announce an engine death: one `EngineFailed` event carrying everything
/// the coordinator needs to re-dispatch (the in-flight and retained
/// request ids); `run_loop` follows up with the terminal `ShutDown`.
fn report_failure<B: Backend>(engine: &Engine<B>, ev_tx: &Sender<EngineEvent>, error: String) {
    eprintln!("engine-{}: FAILED: {error}", engine.id);
    let _ = ev_tx.send(EngineEvent::EngineFailed {
        engine: engine.id,
        error,
        inflight: engine.inflight_request_ids(),
        retained: engine.retained_request_ids(),
    });
}

/// Best-effort extraction of a panic payload's message.
fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Returns true on Shutdown.
fn handle_cmd<B: Backend>(
    engine: &mut Engine<B>,
    cmd: EngineCmd,
    events: &mut Vec<EngineEvent>,
) -> bool {
    match cmd {
        EngineCmd::Assign(item) => {
            if let Err(e) = engine.submit(item) {
                eprintln!("engine-{}: bad work item: {e:#}", engine.id);
            }
            false
        }
        EngineCmd::SetParams { params, invalidate_retained, .. } => {
            if invalidate_retained {
                // Retained KV was computed under the old params — drop it
                // BEFORE installing the new ones so no resume can observe
                // a stale prefix under the new policy.
                engine.invalidate_retained(events);
            }
            if let Err(e) = engine.set_params(&params) {
                eprintln!("engine-{}: weight sync failed: {e:#}", engine.id);
            }
            false
        }
        EngineCmd::StopGeneration { retain } => {
            // Unstarted queue items are re-announced as requeued work via
            // Done events with empty content? No — they were never started;
            // the coordinator tracks its own dispatch list and simply
            // re-queues anything not seen in a Done event after Flushed.
            let _unstarted = engine.stop_generation(events, retain);
            false
        }
        EngineCmd::StopRequest { request_id, retain } => {
            engine.stop_request(events, request_id, retain);
            false
        }
        EngineCmd::ReleaseRetained { request_id, token } => {
            engine.release_retained_request(request_id, token, events);
            false
        }
        EngineCmd::ReleasePrefix { key } => {
            engine.release_prefix(key);
            false
        }
        EngineCmd::Shutdown => true,
    }
}

/// One channel send per flush: a lone event ships as-is; a step that
/// produced several (Done + Trace, flush bursts) ships a single
/// `EngineEvent::Batch` — every mpsc `send` is a heap-allocated queue node
/// plus a wakeup, so per-event sends made the coordinator channel a
/// per-step O(events) cost. The coordinator unpacks in `handle_event`.
fn flush(tx: &Sender<EngineEvent>, events: &mut Vec<EngineEvent>) {
    match events.len() {
        0 => {}
        1 => {
            let _ = tx.send(events.pop().unwrap());
        }
        _ => {
            let _ = tx.send(EngineEvent::Batch(std::mem::take(events)));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::backend::MockBackend;
    use crate::engine::engine::{FinishReason, WorkItem};
    use crate::engine::sampler::SamplingParams;
    use std::collections::VecDeque;
    use std::time::Duration;

    fn mock_pool(engines: usize, slots: usize) -> EnginePool {
        EnginePool::spawn(engines, slots, 0, 7, |_id| {
            Box::new(move || Ok(MockBackend::new(slots, 96)))
        })
        .unwrap()
    }

    fn item(id: u64) -> WorkItem {
        WorkItem {
            request_id: id,
            prompt: vec![1, (id % 20) as i32 + 4, 9].into(),
            resume: vec![],
            max_total: 96,
            sampling: SamplingParams::default(),
            retain: None,
            prefix: None,
        }
    }

    /// Receive the next event, transparently flattening `Batch` sends.
    /// Returns the channel error (timeout / disconnect) instead of
    /// panicking so each test decides how to fail.
    fn next_event(
        rx: &Receiver<EngineEvent>,
        queue: &mut VecDeque<EngineEvent>,
        timeout: Duration,
    ) -> Result<EngineEvent, std::sync::mpsc::RecvTimeoutError> {
        loop {
            if let Some(e) = queue.pop_front() {
                return Ok(e);
            }
            match rx.recv_timeout(timeout)? {
                EngineEvent::Batch(evs) => queue.extend(evs),
                e => return Ok(e),
            }
        }
    }

    #[test]
    fn pool_processes_work_across_engines() {
        let pool = mock_pool(2, 4);
        for i in 0..10 {
            pool.send((i % 2) as usize, EngineCmd::Assign(item(i)));
        }
        let mut done = 0;
        let mut queue = VecDeque::new();
        let deadline = std::time::Instant::now() + Duration::from_secs(20);
        while done < 10 && std::time::Instant::now() < deadline {
            match next_event(&pool.events, &mut queue, Duration::from_secs(5)) {
                Ok(EngineEvent::Done { result, .. }) => {
                    assert!(result.reason.is_complete());
                    done += 1;
                }
                Ok(_) => {}
                Err(_) => break, // the count assert below reports the loss
            }
        }
        assert_eq!(done, 10);
        pool.shutdown();
    }

    /// A step that finishes work emits Done + Trace — those must arrive in
    /// ONE channel send (a Batch), not one send per event.
    #[test]
    fn multi_event_steps_arrive_batched() {
        let pool = mock_pool(1, 2);
        pool.send(0, EngineCmd::Assign(item(3)));
        let deadline = std::time::Instant::now() + Duration::from_secs(10);
        let mut saw_batched_done = false;
        while std::time::Instant::now() < deadline && !saw_batched_done {
            match pool.events.recv_timeout(Duration::from_secs(5)) {
                Ok(EngineEvent::Batch(evs)) => {
                    assert!(evs.len() >= 2, "degenerate batch");
                    assert!(
                        !evs.iter().any(|e| matches!(e, EngineEvent::Batch(_))),
                        "nested batch"
                    );
                    saw_batched_done |=
                        evs.iter().any(|e| matches!(e, EngineEvent::Done { .. }));
                }
                Ok(EngineEvent::Done { .. }) => {
                    panic!("Done delivered outside a Batch alongside its Trace")
                }
                Ok(_) => {}
                Err(e) => panic!("event wait: {e}"),
            }
        }
        assert!(saw_batched_done, "never saw a batched Done event");
        pool.shutdown();
    }

    #[test]
    fn stop_generation_flushes_and_reports() {
        let pool = EnginePool::spawn(1, 2, 0, 7, |_id| {
            Box::new(move || {
                let mut b = MockBackend::new(2, 96);
                b.min_len = 500; // never EOS; LengthCap would need ~93 steps
                b.spread = 1;
                b.decode_delay = Some(Duration::from_millis(5));
                Ok(b)
            })
        })
        .unwrap();
        pool.send(0, EngineCmd::Assign(item(1)));
        pool.send(0, EngineCmd::Assign(item(2)));
        std::thread::sleep(Duration::from_millis(100));
        pool.stop_generation_all();
        let mut partials = 0;
        let mut queue = VecDeque::new();
        let deadline = std::time::Instant::now() + Duration::from_secs(10);
        loop {
            match next_event(&pool.events, &mut queue, Duration::from_secs(5)) {
                Ok(EngineEvent::Done { result, .. }) => {
                    if result.reason == FinishReason::Stopped {
                        partials += 1;
                    }
                }
                Ok(EngineEvent::Flushed { .. }) => break,
                Ok(_) => {}
                Err(_) => break,
            }
            if std::time::Instant::now() > deadline {
                break;
            }
        }
        assert_eq!(partials, 2);
        pool.shutdown();
    }

    /// The stage driver's poll API: empty-channel polls return promptly,
    /// bounded waits deliver events, and a dead pool surfaces as a
    /// `Disconnected` error the caller can route — never a panic.
    #[test]
    fn try_next_and_next_before_poll_without_blocking() {
        let pool = mock_pool(1, 2);
        assert!(pool.try_next().is_none());
        assert!(matches!(pool.try_next_checked(), Ok(None)));
        let t0 = std::time::Instant::now();
        assert!(pool.next_before(t0).is_err()); // past deadline → non-blocking poll
        assert!(t0.elapsed() < Duration::from_millis(100), "past-deadline poll blocked");
        pool.send(0, EngineCmd::Assign(item(9)));
        let deadline = std::time::Instant::now() + Duration::from_secs(10);
        let mut saw_done = false;
        while std::time::Instant::now() < deadline && !saw_done {
            match pool.next_before(deadline) {
                Ok(EngineEvent::Batch(evs)) => {
                    saw_done = evs.iter().any(|e| matches!(e, EngineEvent::Done { .. }))
                }
                Ok(EngineEvent::Done { .. }) => saw_done = true,
                Ok(_) => {}
                Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {}
                Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => break,
            }
        }
        assert!(saw_done, "bounded wait never saw the Done event");
        pool.shutdown();
    }

    /// Once every engine thread exits, the checked poll reports
    /// `Disconnected` instead of masquerading as an empty channel.
    #[test]
    fn try_next_checked_reports_disconnect() {
        let pool = mock_pool(1, 2);
        pool.send(0, EngineCmd::Shutdown);
        let deadline = std::time::Instant::now() + Duration::from_secs(10);
        loop {
            assert!(std::time::Instant::now() < deadline, "never saw disconnect");
            match pool.try_next_checked() {
                Ok(Some(_)) => {} // drain the terminal ShutDown event
                Ok(None) => std::thread::sleep(Duration::from_millis(5)),
                Err(e) => {
                    assert_eq!(e, std::sync::mpsc::RecvTimeoutError::Disconnected);
                    break;
                }
            }
        }
        pool.shutdown();
    }

    /// Threaded retention roundtrip: retain on stop, resume by token, and
    /// get a zero-replay `resumed_from_kv` completion back.
    #[test]
    fn retained_stop_then_resume_roundtrip() {
        let pool = EnginePool::spawn(1, 2, 0, 7, |_id| {
            Box::new(move || {
                let mut b = MockBackend::new(2, 96);
                b.min_len = 40; // long script → guaranteed partial at stop
                b.spread = 1;
                b.decode_delay = Some(Duration::from_millis(2));
                Ok(b)
            })
        })
        .unwrap();
        pool.send(0, EngineCmd::Assign(item(1)));
        std::thread::sleep(Duration::from_millis(60));
        pool.stop_generation_all_with(true);

        let mut queue = VecDeque::new();
        let mut partial: Option<crate::engine::WorkResult> = None;
        let deadline = std::time::Instant::now() + Duration::from_secs(10);
        while std::time::Instant::now() < deadline {
            match next_event(&pool.events, &mut queue, Duration::from_secs(5)) {
                Ok(EngineEvent::Done { result, .. })
                    if result.reason == FinishReason::Stopped =>
                {
                    partial = Some(result)
                }
                Ok(EngineEvent::Flushed { .. }) => break,
                Ok(_) => {}
                Err(_) => break,
            }
        }
        let partial = partial.expect("flushed partial");
        let token = partial.retained.expect("retained token on stop(retain)");

        let mut it = item(1);
        it.resume = partial.new_tokens.clone();
        it.retain = Some(token);
        pool.send(0, EngineCmd::Assign(it));
        let deadline = std::time::Instant::now() + Duration::from_secs(20);
        loop {
            assert!(std::time::Instant::now() < deadline, "resume timed out");
            match next_event(&pool.events, &mut queue, Duration::from_secs(5)) {
                Ok(EngineEvent::Done { result, .. }) if result.reason.is_complete() => {
                    assert!(result.resumed_from_kv, "hinted resume must hit retained KV");
                    assert_eq!(result.replayed, 0);
                    break;
                }
                Ok(_) => {}
                Err(_) => {}
            }
        }
        pool.shutdown();
    }

    /// A panicking backend must surface as `EngineFailed` carrying the
    /// lost request ids, followed by the terminal `ShutDown` — not a
    /// silent thread death.
    #[test]
    fn panicking_backend_reports_engine_failed() {
        use crate::testkit::faulty::{FaultKind, FaultOp, FaultPlan, FaultyBackend};
        let pool = EnginePool::spawn(1, 2, 0, 7, |_id| {
            Box::new(move || {
                let mut b = MockBackend::new(2, 96);
                b.min_len = 500; // long script: the fault hits mid-request
                b.spread = 1;
                Ok(FaultyBackend::new(
                    b,
                    vec![FaultPlan { op: FaultOp::Decode, at_call: 3, kind: FaultKind::Panic }],
                ))
            })
        })
        .unwrap();
        pool.send(0, EngineCmd::Assign(item(1)));
        let mut queue = VecDeque::new();
        let mut failed = None;
        let mut saw_shutdown = false;
        let deadline = std::time::Instant::now() + Duration::from_secs(10);
        while std::time::Instant::now() < deadline && !saw_shutdown {
            match next_event(&pool.events, &mut queue, Duration::from_secs(5)) {
                Ok(EngineEvent::EngineFailed { engine, error, inflight, .. }) => {
                    failed = Some((engine, error, inflight));
                }
                Ok(EngineEvent::ShutDown { .. }) => saw_shutdown = true,
                Ok(_) => {}
                Err(_) => break,
            }
        }
        let (engine, error, inflight) = failed.expect("EngineFailed event");
        assert_eq!(engine, 0);
        assert!(error.contains("panicked"), "unexpected error: {error}");
        assert_eq!(inflight, vec![1], "lost request ids must travel with the failure");
        assert!(saw_shutdown, "EngineFailed must be followed by ShutDown");
        pool.shutdown();
    }

    /// Transient errors retry in place within the budget: the work
    /// completes and no failure event ever surfaces.
    #[test]
    fn transient_errors_retry_in_place() {
        use crate::testkit::faulty::{FaultKind, FaultOp, FaultPlan, FaultyBackend};
        let pool = EnginePool::spawn_supervised(
            1,
            2,
            EngineOpts {
                kv: KvCacheConfig::from_token_budget(0, DEFAULT_BLOCK_SIZE),
                step_token_budget: 0,
            },
            SupervisorOpts { max_retries: 3, retry_backoff_ms: 0 },
            7,
            |_id| {
                Box::new(move || {
                    Ok(FaultyBackend::new(
                        MockBackend::new(2, 96),
                        vec![FaultPlan {
                            op: FaultOp::Decode,
                            at_call: 2,
                            kind: FaultKind::Transient { times: 2 },
                        }],
                    ))
                })
            },
        )
        .unwrap();
        pool.send(0, EngineCmd::Assign(item(1)));
        let mut queue = VecDeque::new();
        let deadline = std::time::Instant::now() + Duration::from_secs(10);
        loop {
            assert!(std::time::Instant::now() < deadline, "work never completed");
            match next_event(&pool.events, &mut queue, Duration::from_secs(5)) {
                Ok(EngineEvent::Done { result, .. }) => {
                    assert!(result.reason.is_complete());
                    break;
                }
                Ok(EngineEvent::EngineFailed { error, .. }) => {
                    panic!("transient fault must not fail the engine: {error}")
                }
                Ok(_) => {}
                Err(e) => panic!("pool channel: {e}"),
            }
        }
        pool.shutdown();
    }

    /// A fatal backend error skips the retry budget and fails the engine
    /// immediately.
    #[test]
    fn fatal_errors_skip_retry_budget() {
        use crate::testkit::faulty::{FaultKind, FaultOp, FaultPlan, FaultyBackend};
        let pool = EnginePool::spawn(1, 2, 0, 7, |_id| {
            Box::new(move || {
                Ok(FaultyBackend::new(
                    MockBackend::new(2, 96),
                    vec![FaultPlan { op: FaultOp::Decode, at_call: 1, kind: FaultKind::Fatal }],
                ))
            })
        })
        .unwrap();
        pool.send(0, EngineCmd::Assign(item(4)));
        let mut queue = VecDeque::new();
        let deadline = std::time::Instant::now() + Duration::from_secs(10);
        loop {
            assert!(std::time::Instant::now() < deadline, "never saw EngineFailed");
            match next_event(&pool.events, &mut queue, Duration::from_secs(5)) {
                Ok(EngineEvent::EngineFailed { error, .. }) => {
                    assert!(error.contains("fatal"), "unexpected error: {error}");
                    break;
                }
                Ok(_) => {}
                Err(e) => panic!("pool channel: {e}"),
            }
        }
        pool.shutdown();
    }

    #[test]
    fn broadcast_params_reaches_engines() {
        let pool = mock_pool(2, 2);
        pool.broadcast_params(1, std::sync::Arc::new(vec![2.5f32]), true);
        // Indirect check: engines keep working after a sync.
        pool.send(0, EngineCmd::Assign(item(5)));
        let mut queue = VecDeque::new();
        let deadline = std::time::Instant::now() + Duration::from_secs(10);
        let mut ok = false;
        while std::time::Instant::now() < deadline {
            if let Ok(EngineEvent::Done { .. }) =
                next_event(&pool.events, &mut queue, Duration::from_secs(5))
            {
                ok = true;
                break;
            }
        }
        assert!(ok);
        pool.shutdown();
    }
}
