//! Token sampling: temperature / top-k / top-p over a vocab logit row,
//! returning the sampled token and its log-probability under the *sampling*
//! distribution — the behaviour log-prob L_i stored with the trajectory
//! (paper Eq. 6). At the paper's defaults (temp 1.0, top-p 1.0, top-k -1)
//! this is exactly the model distribution.

use crate::util::Rng;

#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SamplingParams {
    pub temperature: f64,
    pub top_p: f64,
    /// -1 disables top-k.
    pub top_k: i64,
}

impl Default for SamplingParams {
    fn default() -> Self {
        // Paper Table 3 rollout settings.
        SamplingParams { temperature: 1.0, top_p: 1.0, top_k: -1 }
    }
}

impl SamplingParams {
    pub fn greedy() -> Self {
        SamplingParams { temperature: 0.0, top_p: 1.0, top_k: -1 }
    }
}

/// Sample from one logits row. Returns (token, ln p(token)).
pub fn sample_token(logits: &[f32], params: &SamplingParams, rng: &mut Rng) -> (i32, f32) {
    debug_assert!(!logits.is_empty());
    if params.temperature <= 0.0 {
        // Greedy: probability mass collapses to the argmax.
        let (best, _) = argmax(logits);
        return (best as i32, 0.0);
    }
    let inv_t = 1.0 / params.temperature;
    // Stable softmax at temperature.
    let maxl = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max) as f64;
    let mut probs: Vec<f64> =
        logits.iter().map(|&l| ((l as f64 - maxl) * inv_t).exp()).collect();

    // top-k: zero everything below the k-th largest.
    if params.top_k > 0 && (params.top_k as usize) < probs.len() {
        let mut sorted = probs.clone();
        sorted.sort_by(|a, b| b.partial_cmp(a).unwrap());
        let thresh = sorted[params.top_k as usize - 1];
        for p in probs.iter_mut() {
            if *p < thresh {
                *p = 0.0;
            }
        }
    }

    // top-p (nucleus): keep the smallest prefix of the sorted distribution
    // with cumulative mass >= top_p.
    if params.top_p < 1.0 {
        let total: f64 = probs.iter().sum();
        let mut idx: Vec<usize> = (0..probs.len()).collect();
        idx.sort_by(|&a, &b| probs[b].partial_cmp(&probs[a]).unwrap());
        let mut cum = 0.0;
        let mut keep = vec![false; probs.len()];
        for &i in &idx {
            keep[i] = true;
            cum += probs[i] / total;
            if cum >= params.top_p {
                break;
            }
        }
        for (i, p) in probs.iter_mut().enumerate() {
            if !keep[i] {
                *p = 0.0;
            }
        }
    }

    let total: f64 = probs.iter().sum();
    let token = rng.pick_weighted(&probs);
    let lp = (probs[token] / total).max(1e-300).ln() as f32;
    (token as i32, lp)
}

fn argmax(xs: &[f32]) -> (usize, f32) {
    let mut bi = 0;
    let mut bv = f32::NEG_INFINITY;
    for (i, &x) in xs.iter().enumerate() {
        if x > bv {
            bi = i;
            bv = x;
        }
    }
    (bi, bv)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_picks_argmax() {
        let mut rng = Rng::new(0);
        let logits = [0.1, 5.0, -1.0, 2.0];
        for _ in 0..10 {
            let (t, lp) = sample_token(&logits, &SamplingParams::greedy(), &mut rng);
            assert_eq!(t, 1);
            assert_eq!(lp, 0.0);
        }
    }

    #[test]
    fn temp1_logprob_matches_log_softmax() {
        let mut rng = Rng::new(1);
        let logits = [1.0f32, 2.0, 3.0, 0.5];
        let z: f64 = logits.iter().map(|&l| (l as f64).exp()).sum();
        let (t, lp) = sample_token(&logits, &SamplingParams::default(), &mut rng);
        let want = ((logits[t as usize] as f64).exp() / z).ln();
        assert!((lp as f64 - want).abs() < 1e-5, "{lp} vs {want}");
    }

    #[test]
    fn distribution_roughly_matches_softmax() {
        let mut rng = Rng::new(2);
        let logits = [0.0f32, 1.0, 2.0];
        let mut counts = [0usize; 3];
        let n = 30_000;
        for _ in 0..n {
            let (t, _) = sample_token(&logits, &SamplingParams::default(), &mut rng);
            counts[t as usize] += 1;
        }
        let z: f64 = logits.iter().map(|&l| (l as f64).exp()).sum();
        for i in 0..3 {
            let want = (logits[i] as f64).exp() / z;
            let got = counts[i] as f64 / n as f64;
            assert!((got - want).abs() < 0.02, "token {i}: {got} vs {want}");
        }
    }

    #[test]
    fn top_k_restricts_support() {
        let mut rng = Rng::new(3);
        let logits = [0.0f32, 1.0, 2.0, 3.0];
        let p = SamplingParams { temperature: 1.0, top_p: 1.0, top_k: 2 };
        for _ in 0..200 {
            let (t, _) = sample_token(&logits, &p, &mut rng);
            assert!(t == 2 || t == 3, "token {t} outside top-2");
        }
    }

    #[test]
    fn top_p_keeps_head_of_distribution() {
        let mut rng = Rng::new(4);
        // p ≈ [0.64, 0.24, 0.09, 0.03]; top_p=0.7 keeps tokens {0, 1}.
        let logits = [3.0f32, 2.0, 1.0, 0.0];
        let p = SamplingParams { temperature: 1.0, top_p: 0.7, top_k: -1 };
        for _ in 0..200 {
            let (t, _) = sample_token(&logits, &p, &mut rng);
            assert!(t <= 1, "token {t} outside nucleus");
        }
    }

    #[test]
    fn low_temperature_sharpens() {
        let mut rng = Rng::new(5);
        let logits = [1.0f32, 1.5];
        let p = SamplingParams { temperature: 0.1, top_p: 1.0, top_k: -1 };
        let hits = (0..500)
            .filter(|_| sample_token(&logits, &p, &mut rng).0 == 1)
            .count();
        assert!(hits > 480, "{hits}");
    }

    #[test]
    fn sampling_is_deterministic_given_rng() {
        let logits = [0.3f32, 0.2, 0.9, -0.5];
        let a: Vec<i32> = {
            let mut rng = Rng::new(9);
            (0..20).map(|_| sample_token(&logits, &SamplingParams::default(), &mut rng).0).collect()
        };
        let b: Vec<i32> = {
            let mut rng = Rng::new(9);
            (0..20).map(|_| sample_token(&logits, &SamplingParams::default(), &mut rng).0).collect()
        };
        assert_eq!(a, b);
    }
}
