//! Token sampling: temperature / top-k / top-p over a vocab logit row,
//! returning the sampled token and its log-probability under the *sampling*
//! distribution — the behaviour log-prob L_i stored with the trajectory
//! (paper Eq. 6). At the paper's defaults (temp 1.0, top-p 1.0, top-k -1)
//! this is exactly the model distribution.
//!
//! The hot path (`sample_token_with`) is steady-state allocation-free: all
//! working storage lives in a caller-owned [`SamplerScratch`] that sizes
//! itself to the vocab on first use and is reused for every subsequent
//! call. Top-k uses in-place partial selection (`select_nth_unstable_by`)
//! instead of a full sorted clone; top-p sorts a reusable index array
//! in-place (unstable sort with an index tiebreak — identical order to the
//! stable sort it replaces, without the stable sort's temp buffer).

use crate::util::Rng;

/// Sampling hyperparameters for one generation request.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SamplingParams {
    /// Softmax temperature; ≤ 0 means greedy argmax.
    pub temperature: f64,
    /// Nucleus mass; 1.0 disables top-p.
    pub top_p: f64,
    /// -1 disables top-k.
    pub top_k: i64,
}

impl Default for SamplingParams {
    fn default() -> Self {
        // Paper Table 3 rollout settings.
        SamplingParams { temperature: 1.0, top_p: 1.0, top_k: -1 }
    }
}

impl SamplingParams {
    /// Greedy decoding (temperature 0): deterministic argmax, no RNG use.
    pub fn greedy() -> Self {
        SamplingParams { temperature: 0.0, top_p: 1.0, top_k: -1 }
    }
}

/// Reusable sampling workspace. One per engine (the engine's decode loop is
/// single-threaded); sized lazily to the largest vocab seen, then constant.
#[derive(Default)]
pub struct SamplerScratch {
    /// Unnormalized probabilities exp((l - max) / T), zeroed outside the
    /// top-k / top-p support.
    probs: Vec<f64>,
    /// Index array for the top-p nucleus sort.
    idx: Vec<u32>,
    /// Value copy consumed by top-k partial selection.
    sel: Vec<f64>,
}

impl SamplerScratch {
    /// Fresh (empty) workspace; sizes itself on first use.
    pub fn new() -> SamplerScratch {
        SamplerScratch::default()
    }

    /// Current workspace capacity (scratch-reuse assertions in tests).
    pub fn capacity(&self) -> usize {
        self.probs.capacity()
    }
}

/// Sample from one logits row using caller-owned scratch storage.
/// Returns (token, ln p(token)). Behaviour is bit-identical to the
/// straightforward allocating implementation (`reference::sample_token_ref`)
/// for the same `Rng` stream: identical token picks, identical log-prob
/// bits, identical RNG consumption (one `next_f64` per non-greedy call).
pub fn sample_token_with(
    logits: &[f32],
    params: &SamplingParams,
    rng: &mut Rng,
    scratch: &mut SamplerScratch,
) -> (i32, f32) {
    debug_assert!(!logits.is_empty());
    if params.temperature <= 0.0 {
        // Greedy: probability mass collapses to the argmax.
        let (best, _) = argmax(logits);
        return (best as i32, 0.0);
    }
    let n = logits.len();
    let inv_t = 1.0 / params.temperature;
    // Stable softmax at temperature. The subtract/multiply/exp sequence and
    // the left-to-right total accumulation match the reference bit-for-bit.
    let maxl = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max) as f64;
    scratch.probs.clear();
    scratch.probs.extend(logits.iter().map(|&l| ((l as f64 - maxl) * inv_t).exp()));
    let probs = &mut scratch.probs[..];

    // top-k: keep exactly the k largest (stable order among ties — the
    // first tokens in index order win), zero the rest. Partial selection
    // finds the k-th largest value without sorting the whole vocab.
    if params.top_k > 0 && (params.top_k as usize) < n {
        let k = params.top_k as usize;
        scratch.sel.clear();
        scratch.sel.extend_from_slice(probs);
        let (_, kth, _) = scratch
            .sel
            .select_nth_unstable_by(k - 1, |a, b| b.partial_cmp(a).unwrap());
        let thresh = *kth;
        // At most k-1 entries are strictly greater than the k-th largest;
        // fill the remaining slots from the ties in index order.
        let greater = probs.iter().filter(|&&p| p > thresh).count();
        let mut tie_quota = k - greater;
        for p in probs.iter_mut() {
            if *p > thresh {
                continue;
            }
            if *p == thresh && tie_quota > 0 {
                tie_quota -= 1;
                continue;
            }
            *p = 0.0;
        }
    }

    // top-p (nucleus): keep the smallest prefix of the sorted distribution
    // with cumulative mass >= top_p.
    if params.top_p < 1.0 {
        let total: f64 = probs.iter().sum();
        scratch.idx.clear();
        scratch.idx.extend(0..n as u32);
        // Unstable in-place sort with an explicit index tiebreak reproduces
        // the stable by-probability order without a merge-sort temp buffer.
        scratch.idx.sort_unstable_by(|&a, &b| {
            probs[b as usize]
                .partial_cmp(&probs[a as usize])
                .unwrap()
                .then(a.cmp(&b))
        });
        let mut cum = 0.0;
        let mut cut = n;
        for (rank, &i) in scratch.idx.iter().enumerate() {
            cum += probs[i as usize] / total;
            if cum >= params.top_p {
                cut = rank + 1;
                break;
            }
        }
        for &i in &scratch.idx[cut..] {
            probs[i as usize] = 0.0;
        }
    }

    // The final total over the (masked) support is accumulated left to
    // right — the same order `pick_weighted` used — so the threshold walk
    // sees bit-identical values.
    let total: f64 = probs.iter().sum();
    let token = pick_weighted_total(rng, probs, total);
    let lp = (probs[token] / total).max(1e-300).ln() as f32;
    (token as i32, lp)
}

/// Convenience wrapper for cold paths and tests: same behaviour as
/// [`sample_token_with`] with a throwaway scratch.
pub fn sample_token(logits: &[f32], params: &SamplingParams, rng: &mut Rng) -> (i32, f32) {
    let mut scratch = SamplerScratch::new();
    sample_token_with(logits, params, rng, &mut scratch)
}

/// `Rng::pick_weighted` with the total precomputed by the caller (the
/// sampler already has it); identical threshold walk, one fewer pass.
#[inline]
fn pick_weighted_total(rng: &mut Rng, weights: &[f64], total: f64) -> usize {
    let mut x = rng.next_f64() * total;
    for (i, w) in weights.iter().enumerate() {
        x -= w;
        if x <= 0.0 {
            return i;
        }
    }
    weights.len() - 1
}

fn argmax(xs: &[f32]) -> (usize, f32) {
    let mut bi = 0;
    let mut bv = f32::NEG_INFINITY;
    for (i, &x) in xs.iter().enumerate() {
        if x > bv {
            bi = i;
            bv = x;
        }
    }
    (bi, bv)
}

pub mod reference {
    //! The straightforward allocating sampler (pre-scratch seed code, with
    //! the sanctioned exact-k tie fix). Kept as the differential oracle for
    //! the golden-determinism tests and the "before" rows of
    //! `benches/micro.rs` — NOT used on any production path.

    use super::{argmax, SamplingParams};
    use crate::util::Rng;

    /// Allocating reference implementation of [`super::sample_token_with`].
    pub fn sample_token_ref(
        logits: &[f32],
        params: &SamplingParams,
        rng: &mut Rng,
    ) -> (i32, f32) {
        debug_assert!(!logits.is_empty());
        if params.temperature <= 0.0 {
            let (best, _) = argmax(logits);
            return (best as i32, 0.0);
        }
        let inv_t = 1.0 / params.temperature;
        let maxl = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max) as f64;
        let mut probs: Vec<f64> =
            logits.iter().map(|&l| ((l as f64 - maxl) * inv_t).exp()).collect();

        // top-k: keep exactly k (stable order among ties).
        if params.top_k > 0 && (params.top_k as usize) < probs.len() {
            let k = params.top_k as usize;
            let mut sorted = probs.clone();
            sorted.sort_by(|a, b| b.partial_cmp(a).unwrap());
            let thresh = sorted[k - 1];
            let greater = probs.iter().filter(|&&p| p > thresh).count();
            let mut tie_quota = k - greater;
            for p in probs.iter_mut() {
                if *p > thresh {
                    continue;
                }
                if *p == thresh && tie_quota > 0 {
                    tie_quota -= 1;
                    continue;
                }
                *p = 0.0;
            }
        }

        // top-p (nucleus).
        if params.top_p < 1.0 {
            let total: f64 = probs.iter().sum();
            let mut idx: Vec<usize> = (0..probs.len()).collect();
            idx.sort_by(|&a, &b| probs[b].partial_cmp(&probs[a]).unwrap());
            let mut cum = 0.0;
            let mut keep = vec![false; probs.len()];
            for &i in &idx {
                keep[i] = true;
                cum += probs[i] / total;
                if cum >= params.top_p {
                    break;
                }
            }
            for (i, p) in probs.iter_mut().enumerate() {
                if !keep[i] {
                    *p = 0.0;
                }
            }
        }

        let total: f64 = probs.iter().sum();
        let token = rng.pick_weighted(&probs);
        let lp = (probs[token] / total).max(1e-300).ln() as f32;
        (token as i32, lp)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_picks_argmax() {
        let mut rng = Rng::new(0);
        let logits = [0.1, 5.0, -1.0, 2.0];
        for _ in 0..10 {
            let (t, lp) = sample_token(&logits, &SamplingParams::greedy(), &mut rng);
            assert_eq!(t, 1);
            assert_eq!(lp, 0.0);
        }
    }

    #[test]
    fn temp1_logprob_matches_log_softmax() {
        let mut rng = Rng::new(1);
        let logits = [1.0f32, 2.0, 3.0, 0.5];
        let z: f64 = logits.iter().map(|&l| (l as f64).exp()).sum();
        let (t, lp) = sample_token(&logits, &SamplingParams::default(), &mut rng);
        let want = ((logits[t as usize] as f64).exp() / z).ln();
        assert!((lp as f64 - want).abs() < 1e-5, "{lp} vs {want}");
    }

    #[test]
    fn distribution_roughly_matches_softmax() {
        let mut rng = Rng::new(2);
        let logits = [0.0f32, 1.0, 2.0];
        let mut counts = [0usize; 3];
        let n = 30_000;
        let mut scratch = SamplerScratch::new();
        for _ in 0..n {
            let (t, _) =
                sample_token_with(&logits, &SamplingParams::default(), &mut rng, &mut scratch);
            counts[t as usize] += 1;
        }
        let z: f64 = logits.iter().map(|&l| (l as f64).exp()).sum();
        for i in 0..3 {
            let want = (logits[i] as f64).exp() / z;
            let got = counts[i] as f64 / n as f64;
            assert!((got - want).abs() < 0.02, "token {i}: {got} vs {want}");
        }
    }

    #[test]
    fn top_k_restricts_support() {
        let mut rng = Rng::new(3);
        let logits = [0.0f32, 1.0, 2.0, 3.0];
        let p = SamplingParams { temperature: 1.0, top_p: 1.0, top_k: 2 };
        for _ in 0..200 {
            let (t, _) = sample_token(&logits, &p, &mut rng);
            assert!(t == 2 || t == 3, "token {t} outside top-2");
        }
    }

    #[test]
    fn top_k_with_ties_keeps_exactly_k() {
        // Four-way tie at the top: the old `*p < thresh` filter kept all
        // four; exact-k keeps the FIRST two in index order.
        let mut rng = Rng::new(11);
        let logits = [1.0f32, 1.0, 1.0, 1.0, 0.0];
        let p = SamplingParams { temperature: 1.0, top_p: 1.0, top_k: 2 };
        let mut scratch = SamplerScratch::new();
        for _ in 0..400 {
            let (t, lp) = sample_token_with(&logits, &p, &mut rng, &mut scratch);
            assert!(t == 0 || t == 1, "token {t} outside exact top-2 (tie leak)");
            // Two equal survivors → p = 1/2 each.
            assert!((lp - 0.5f32.ln()).abs() < 1e-6, "lp {lp}");
        }
    }

    #[test]
    fn top_k_ties_below_threshold_are_dropped() {
        // k-th largest is part of a tie that STARTS inside the top-k: keep
        // greater values plus ties in index order until the quota fills.
        let mut rng = Rng::new(12);
        let logits = [2.0f32, 1.0, 1.0, 1.0];
        let p = SamplingParams { temperature: 1.0, top_p: 1.0, top_k: 2 };
        for _ in 0..400 {
            let (t, _) = sample_token(&logits, &p, &mut rng);
            assert!(t == 0 || t == 1, "token {t}: tie quota leaked past k");
        }
    }

    #[test]
    fn top_p_keeps_head_of_distribution() {
        let mut rng = Rng::new(4);
        // p ≈ [0.64, 0.24, 0.09, 0.03]; top_p=0.7 keeps tokens {0, 1}.
        let logits = [3.0f32, 2.0, 1.0, 0.0];
        let p = SamplingParams { temperature: 1.0, top_p: 0.7, top_k: -1 };
        for _ in 0..200 {
            let (t, _) = sample_token(&logits, &p, &mut rng);
            assert!(t <= 1, "token {t} outside nucleus");
        }
    }

    #[test]
    fn low_temperature_sharpens() {
        let mut rng = Rng::new(5);
        let logits = [1.0f32, 1.5];
        let p = SamplingParams { temperature: 0.1, top_p: 1.0, top_k: -1 };
        let hits = (0..500)
            .filter(|_| sample_token(&logits, &p, &mut rng).0 == 1)
            .count();
        assert!(hits > 480, "{hits}");
    }

    #[test]
    fn sampling_is_deterministic_given_rng() {
        let logits = [0.3f32, 0.2, 0.9, -0.5];
        let a: Vec<i32> = {
            let mut rng = Rng::new(9);
            (0..20).map(|_| sample_token(&logits, &SamplingParams::default(), &mut rng).0).collect()
        };
        let b: Vec<i32> = {
            let mut rng = Rng::new(9);
            (0..20).map(|_| sample_token(&logits, &SamplingParams::default(), &mut rng).0).collect()
        };
        assert_eq!(a, b);
    }

    /// The tentpole contract: the scratch path is bit-identical to the
    /// allocating reference — same tokens, same log-prob BITS, same RNG
    /// consumption — across temperatures, top-k, top-p, and shared scratch.
    #[test]
    fn scratch_path_matches_reference_bitwise() {
        let mut gen = Rng::new(77);
        let mut scratch = SamplerScratch::new();
        let param_grid = [
            SamplingParams::default(),
            SamplingParams { temperature: 0.7, top_p: 1.0, top_k: -1 },
            SamplingParams { temperature: 1.0, top_p: 0.9, top_k: -1 },
            SamplingParams { temperature: 1.0, top_p: 1.0, top_k: 8 },
            SamplingParams { temperature: 1.3, top_p: 0.8, top_k: 12 },
            SamplingParams { temperature: 0.5, top_p: 0.95, top_k: 3 },
        ];
        for case in 0..500 {
            let n = 2 + (gen.below(63) as usize);
            let logits: Vec<f32> =
                (0..n).map(|_| (gen.next_f64() * 8.0 - 4.0) as f32).collect();
            let params = param_grid[case % param_grid.len()];
            let mut rng_a = Rng::new(1000 + case as u64);
            let mut rng_b = rng_a.clone();
            let (ta, lpa) = reference::sample_token_ref(&logits, &params, &mut rng_a);
            let (tb, lpb) = sample_token_with(&logits, &params, &mut rng_b, &mut scratch);
            assert_eq!(ta, tb, "case {case}: token diverged ({params:?})");
            assert_eq!(
                lpa.to_bits(),
                lpb.to_bits(),
                "case {case}: logprob bits diverged ({params:?})"
            );
            assert_eq!(
                rng_a.next_u64(),
                rng_b.next_u64(),
                "case {case}: rng stream diverged"
            );
        }
    }

    /// Scratch capacity stabilizes after the first call at the max vocab —
    /// later calls never regrow it (the alloc-free contract's mechanism).
    #[test]
    fn scratch_capacity_is_stable_after_warmup() {
        let mut rng = Rng::new(6);
        let mut scratch = SamplerScratch::new();
        let logits: Vec<f32> = (0..48).map(|i| (i % 7) as f32 * 0.4).collect();
        let p = SamplingParams { temperature: 1.0, top_p: 0.9, top_k: 8 };
        sample_token_with(&logits, &p, &mut rng, &mut scratch);
        let cap = scratch.capacity();
        assert!(cap >= 48);
        for _ in 0..200 {
            sample_token_with(&logits, &p, &mut rng, &mut scratch);
            assert_eq!(scratch.capacity(), cap, "scratch regrew in steady state");
        }
    }
}
