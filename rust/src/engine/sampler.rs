//! Token sampling: temperature / top-k / top-p over a vocab logit row,
//! returning the sampled token and its log-probability under the *sampling*
//! distribution — the behaviour log-prob L_i stored with the trajectory
//! (paper Eq. 6). At the paper's defaults (temp 1.0, top-p 1.0, top-k -1)
//! this is exactly the model distribution.
//!
//! The hot path (`sample_token_dispatched`) is steady-state allocation-free
//! (all working storage lives in a caller-owned [`SamplerScratch`]) and
//! runs its data-parallel pieces — max/argmax, the softmax exp argument
//! pipeline, top-k threshold masking, the nucleus gather-divide — on the
//! SIMD arm the engine detected at construction ([`super::simd`]:
//! scalar / AVX2 / AVX-512). Every arm is **bit-identical** to the scalar
//! reference for NaN-free logits: same tokens, same log-prob bits, same
//! RNG consumption (the contract the engine goldens rely on; see the
//! differential fuzz below and `super::simd`'s module docs).
//!
//! Top-k uses in-place partial selection (`select_nth_unstable_by`)
//! instead of a full sorted clone; top-p sorts a reusable index array
//! in-place (unstable sort with an index tiebreak — identical order to the
//! stable sort it replaces, without the stable sort's temp buffer).

use super::simd::{self, SamplerDispatch};
use crate::util::Rng;

/// Sampling hyperparameters for one generation request.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SamplingParams {
    /// Softmax temperature; ≤ 0 means greedy argmax.
    pub temperature: f64,
    /// Nucleus mass; 1.0 disables top-p.
    pub top_p: f64,
    /// -1 disables top-k.
    pub top_k: i64,
}

impl Default for SamplingParams {
    fn default() -> Self {
        // Paper Table 3 rollout settings.
        SamplingParams { temperature: 1.0, top_p: 1.0, top_k: -1 }
    }
}

impl SamplingParams {
    /// Greedy decoding (temperature 0): deterministic argmax, no RNG use.
    pub fn greedy() -> Self {
        SamplingParams { temperature: 0.0, top_p: 1.0, top_k: -1 }
    }
}

/// Reusable sampling workspace. One per engine (the engine's decode loop is
/// single-threaded); sized lazily to the largest vocab seen, then constant.
#[derive(Default)]
pub struct SamplerScratch {
    /// Unnormalized probabilities exp((l - max) / T), zeroed outside the
    /// top-k / top-p support.
    probs: Vec<f64>,
    /// Index array for the top-p nucleus sort.
    idx: Vec<u32>,
    /// Value copy consumed by top-k partial selection.
    sel: Vec<f64>,
}

impl SamplerScratch {
    /// Fresh (empty) workspace; sizes itself on first use.
    pub fn new() -> SamplerScratch {
        SamplerScratch::default()
    }

    /// Current workspace capacity (scratch-reuse assertions in tests).
    pub fn capacity(&self) -> usize {
        self.probs.capacity()
    }
}

/// Sample from one logits row on an explicit SIMD dispatch arm, using
/// caller-owned scratch storage. Returns (token, ln p(token)).
///
/// Behaviour is bit-identical across every [`SamplerDispatch`] arm and to
/// the straightforward allocating implementation
/// (`reference::sample_token_ref`) for the same `Rng` stream: identical
/// token picks, identical log-prob bits, identical RNG consumption (one
/// `next_f64` per non-greedy call). Logit rows must be NaN-free (`-inf`
/// entries are fine); the backends never produce NaN logits.
pub fn sample_token_dispatched(
    logits: &[f32],
    params: &SamplingParams,
    rng: &mut Rng,
    scratch: &mut SamplerScratch,
    dispatch: SamplerDispatch,
) -> (i32, f32) {
    debug_assert!(!logits.is_empty());
    if params.temperature <= 0.0 {
        // Greedy: probability mass collapses to the argmax.
        let best = simd::argmax_f32(dispatch, logits);
        return (best as i32, 0.0);
    }
    let n = logits.len();
    let inv_t = 1.0 / params.temperature;
    // Stable softmax at temperature. The subtract/multiply/exp sequence and
    // the left-to-right total accumulation match the reference bit-for-bit
    // on every dispatch arm (the exp itself is scalar libm everywhere).
    let maxl = simd::max_f32(dispatch, logits) as f64;
    simd::exp_scaled(dispatch, logits, maxl, inv_t, &mut scratch.probs);
    let probs = &mut scratch.probs[..];

    // top-k: keep exactly the k largest (stable order among ties — the
    // first tokens in index order win), zero the rest. Partial selection
    // finds the k-th largest value without sorting the whole vocab.
    if params.top_k > 0 && (params.top_k as usize) < n {
        let k = params.top_k as usize;
        scratch.sel.clear();
        scratch.sel.extend_from_slice(probs);
        let (_, kth, _) = scratch
            .sel
            .select_nth_unstable_by(k - 1, |a, b| b.partial_cmp(a).unwrap());
        let thresh = *kth;
        // At most k-1 entries are strictly greater than the k-th largest;
        // fill the remaining slots from the ties in index order.
        let greater = simd::count_greater(dispatch, probs, thresh);
        let tie_quota = k - greater;
        simd::mask_top_k(dispatch, probs, thresh, tie_quota);
    }

    // top-p (nucleus): keep the smallest prefix of the sorted distribution
    // with cumulative mass >= top_p.
    if params.top_p < 1.0 {
        let total: f64 = probs.iter().sum();
        scratch.idx.clear();
        scratch.idx.extend(0..n as u32);
        // Unstable in-place sort with an explicit index tiebreak reproduces
        // the stable by-probability order without a merge-sort temp buffer.
        scratch.idx.sort_unstable_by(|&a, &b| {
            probs[b as usize]
                .partial_cmp(&probs[a as usize])
                .unwrap()
                .then(a.cmp(&b))
        });
        let cut = simd::nucleus_cut(dispatch, probs, &scratch.idx, total, params.top_p);
        for &i in &scratch.idx[cut..] {
            probs[i as usize] = 0.0;
        }
    }

    // The final total over the (masked) support is accumulated left to
    // right — the same order `pick_weighted` used — so the threshold walk
    // sees bit-identical values.
    let total: f64 = probs.iter().sum();
    let token = pick_weighted_total(rng, probs, total);
    let lp = nucleus_tail_logprob(probs[token], total);
    (token as i32, lp)
}

/// Sample from one logits row using caller-owned scratch storage, on the
/// scalar reference arm. Returns (token, ln p(token)); see
/// [`sample_token_dispatched`] for the bit-identity contract. Cold paths
/// and the differential oracle use this; the engine's decode loop calls
/// the dispatched variant with its detected arm.
pub fn sample_token_with(
    logits: &[f32],
    params: &SamplingParams,
    rng: &mut Rng,
    scratch: &mut SamplerScratch,
) -> (i32, f32) {
    sample_token_dispatched(logits, params, rng, scratch, SamplerDispatch::Scalar)
}

/// Convenience wrapper for cold paths and tests: same behaviour as
/// [`sample_token_with`] with a throwaway scratch.
pub fn sample_token(logits: &[f32], params: &SamplingParams, rng: &mut Rng) -> (i32, f32) {
    let mut scratch = SamplerScratch::new();
    sample_token_with(logits, params, rng, &mut scratch)
}

/// Sampling log-prob of the picked token: ln of the *quotient* p/total,
/// clamped AFTER the division so a fully-degenerate row can never emit
/// `-inf` or NaN. `total` is a left-to-right sum of non-negatives, so
/// `total >= p >= 0` and the quotient is in [0, 1] — or NaN on an all-NaN
/// row (every logit `-inf`), which `f64::max` also maps to the 1e-300
/// floor. Either way the result is finite (ln 1e-300 ≈ -690.78). Clamping
/// the numerator instead would leave `0/total = 0 → ln = -inf` reachable.
#[inline]
fn nucleus_tail_logprob(p: f64, total: f64) -> f32 {
    ((p / total).max(1e-300)).ln() as f32
}

/// `Rng::pick_weighted` with the total precomputed by the caller (the
/// sampler already has it); identical threshold walk, one fewer pass.
#[inline]
fn pick_weighted_total(rng: &mut Rng, weights: &[f64], total: f64) -> usize {
    let mut x = rng.next_f64() * total;
    for (i, w) in weights.iter().enumerate() {
        x -= w;
        if x <= 0.0 {
            return i;
        }
    }
    weights.len() - 1
}

fn argmax(xs: &[f32]) -> (usize, f32) {
    let mut bi = 0;
    let mut bv = f32::NEG_INFINITY;
    for (i, &x) in xs.iter().enumerate() {
        if x > bv {
            bi = i;
            bv = x;
        }
    }
    (bi, bv)
}

pub mod reference {
    //! The straightforward allocating sampler (pre-scratch seed code, with
    //! the sanctioned exact-k tie fix). Kept as the differential oracle for
    //! the golden-determinism tests, the scalar-vs-SIMD bit-identity fuzz,
    //! and the "before" rows of `benches/micro.rs` — NOT used on any
    //! production path.

    use super::{argmax, SamplingParams};
    use crate::util::Rng;

    /// Allocating reference implementation of [`super::sample_token_with`].
    pub fn sample_token_ref(
        logits: &[f32],
        params: &SamplingParams,
        rng: &mut Rng,
    ) -> (i32, f32) {
        debug_assert!(!logits.is_empty());
        if params.temperature <= 0.0 {
            let (best, _) = argmax(logits);
            return (best as i32, 0.0);
        }
        let inv_t = 1.0 / params.temperature;
        let maxl = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max) as f64;
        let mut probs: Vec<f64> =
            logits.iter().map(|&l| ((l as f64 - maxl) * inv_t).exp()).collect();

        // top-k: keep exactly k (stable order among ties).
        if params.top_k > 0 && (params.top_k as usize) < probs.len() {
            let k = params.top_k as usize;
            let mut sorted = probs.clone();
            sorted.sort_by(|a, b| b.partial_cmp(a).unwrap());
            let thresh = sorted[k - 1];
            let greater = probs.iter().filter(|&&p| p > thresh).count();
            let mut tie_quota = k - greater;
            for p in probs.iter_mut() {
                if *p > thresh {
                    continue;
                }
                if *p == thresh && tie_quota > 0 {
                    tie_quota -= 1;
                    continue;
                }
                *p = 0.0;
            }
        }

        // top-p (nucleus).
        if params.top_p < 1.0 {
            let total: f64 = probs.iter().sum();
            let mut idx: Vec<usize> = (0..probs.len()).collect();
            idx.sort_by(|&a, &b| probs[b].partial_cmp(&probs[a]).unwrap());
            let mut cum = 0.0;
            let mut keep = vec![false; probs.len()];
            for &i in &idx {
                keep[i] = true;
                cum += probs[i] / total;
                if cum >= params.top_p {
                    break;
                }
            }
            for (i, p) in probs.iter_mut().enumerate() {
                if !keep[i] {
                    *p = 0.0;
                }
            }
        }

        let total: f64 = probs.iter().sum();
        let token = rng.pick_weighted(&probs);
        let lp = (probs[token] / total).max(1e-300).ln() as f32;
        (token as i32, lp)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_picks_argmax() {
        let mut rng = Rng::new(0);
        let logits = [0.1, 5.0, -1.0, 2.0];
        for _ in 0..10 {
            let (t, lp) = sample_token(&logits, &SamplingParams::greedy(), &mut rng);
            assert_eq!(t, 1);
            assert_eq!(lp, 0.0);
        }
    }

    #[test]
    fn temp1_logprob_matches_log_softmax() {
        let mut rng = Rng::new(1);
        let logits = [1.0f32, 2.0, 3.0, 0.5];
        let z: f64 = logits.iter().map(|&l| (l as f64).exp()).sum();
        let (t, lp) = sample_token(&logits, &SamplingParams::default(), &mut rng);
        let want = ((logits[t as usize] as f64).exp() / z).ln();
        assert!((lp as f64 - want).abs() < 1e-5, "{lp} vs {want}");
    }

    #[test]
    fn distribution_roughly_matches_softmax() {
        let mut rng = Rng::new(2);
        let logits = [0.0f32, 1.0, 2.0];
        let mut counts = [0usize; 3];
        let n = 30_000;
        let mut scratch = SamplerScratch::new();
        for _ in 0..n {
            let (t, _) =
                sample_token_with(&logits, &SamplingParams::default(), &mut rng, &mut scratch);
            counts[t as usize] += 1;
        }
        let z: f64 = logits.iter().map(|&l| (l as f64).exp()).sum();
        for i in 0..3 {
            let want = (logits[i] as f64).exp() / z;
            let got = counts[i] as f64 / n as f64;
            assert!((got - want).abs() < 0.02, "token {i}: {got} vs {want}");
        }
    }

    #[test]
    fn top_k_restricts_support() {
        let mut rng = Rng::new(3);
        let logits = [0.0f32, 1.0, 2.0, 3.0];
        let p = SamplingParams { temperature: 1.0, top_p: 1.0, top_k: 2 };
        for _ in 0..200 {
            let (t, _) = sample_token(&logits, &p, &mut rng);
            assert!(t == 2 || t == 3, "token {t} outside top-2");
        }
    }

    #[test]
    fn top_k_with_ties_keeps_exactly_k() {
        // Four-way tie at the top: the old `*p < thresh` filter kept all
        // four; exact-k keeps the FIRST two in index order.
        let mut rng = Rng::new(11);
        let logits = [1.0f32, 1.0, 1.0, 1.0, 0.0];
        let p = SamplingParams { temperature: 1.0, top_p: 1.0, top_k: 2 };
        let mut scratch = SamplerScratch::new();
        for _ in 0..400 {
            let (t, lp) = sample_token_with(&logits, &p, &mut rng, &mut scratch);
            assert!(t == 0 || t == 1, "token {t} outside exact top-2 (tie leak)");
            // Two equal survivors → p = 1/2 each.
            assert!((lp - 0.5f32.ln()).abs() < 1e-6, "lp {lp}");
        }
    }

    #[test]
    fn top_k_ties_below_threshold_are_dropped() {
        // k-th largest is part of a tie that STARTS inside the top-k: keep
        // greater values plus ties in index order until the quota fills.
        let mut rng = Rng::new(12);
        let logits = [2.0f32, 1.0, 1.0, 1.0];
        let p = SamplingParams { temperature: 1.0, top_p: 1.0, top_k: 2 };
        for _ in 0..400 {
            let (t, _) = sample_token(&logits, &p, &mut rng);
            assert!(t == 0 || t == 1, "token {t}: tie quota leaked past k");
        }
    }

    #[test]
    fn top_p_keeps_head_of_distribution() {
        let mut rng = Rng::new(4);
        // p ≈ [0.64, 0.24, 0.09, 0.03]; top_p=0.7 keeps tokens {0, 1}.
        let logits = [3.0f32, 2.0, 1.0, 0.0];
        let p = SamplingParams { temperature: 1.0, top_p: 0.7, top_k: -1 };
        for _ in 0..200 {
            let (t, _) = sample_token(&logits, &p, &mut rng);
            assert!(t <= 1, "token {t} outside nucleus");
        }
    }

    #[test]
    fn low_temperature_sharpens() {
        let mut rng = Rng::new(5);
        let logits = [1.0f32, 1.5];
        let p = SamplingParams { temperature: 0.1, top_p: 1.0, top_k: -1 };
        let hits = (0..500)
            .filter(|_| sample_token(&logits, &p, &mut rng).0 == 1)
            .count();
        assert!(hits > 480, "{hits}");
    }

    #[test]
    fn sampling_is_deterministic_given_rng() {
        let logits = [0.3f32, 0.2, 0.9, -0.5];
        let a: Vec<i32> = {
            let mut rng = Rng::new(9);
            (0..20).map(|_| sample_token(&logits, &SamplingParams::default(), &mut rng).0).collect()
        };
        let b: Vec<i32> = {
            let mut rng = Rng::new(9);
            (0..20).map(|_| sample_token(&logits, &SamplingParams::default(), &mut rng).0).collect()
        };
        assert_eq!(a, b);
    }

    /// The tentpole contract, promoted to a scalar-vs-SIMD bit-identity
    /// oracle: at EVERY dispatch level this machine supports, the scratch
    /// path is bit-identical to the allocating reference — same tokens,
    /// same log-prob BITS, same RNG consumption — across temperatures,
    /// top-k, top-p, and shared scratch. 500 cases per level, same case
    /// stream at each level.
    #[test]
    fn dispatch_arms_match_reference_bitwise() {
        let param_grid = [
            SamplingParams::default(),
            SamplingParams { temperature: 0.7, top_p: 1.0, top_k: -1 },
            SamplingParams { temperature: 1.0, top_p: 0.9, top_k: -1 },
            SamplingParams { temperature: 1.0, top_p: 1.0, top_k: 8 },
            SamplingParams { temperature: 1.3, top_p: 0.8, top_k: 12 },
            SamplingParams { temperature: 0.5, top_p: 0.95, top_k: 3 },
        ];
        for dispatch in SamplerDispatch::available() {
            let mut gen = Rng::new(77);
            let mut scratch = SamplerScratch::new();
            for case in 0..500 {
                let n = 2 + (gen.below(63) as usize);
                let logits: Vec<f32> =
                    (0..n).map(|_| (gen.next_f64() * 8.0 - 4.0) as f32).collect();
                let params = param_grid[case % param_grid.len()];
                let mut rng_a = Rng::new(1000 + case as u64);
                let mut rng_b = rng_a.clone();
                let (ta, lpa) = reference::sample_token_ref(&logits, &params, &mut rng_a);
                let (tb, lpb) =
                    sample_token_dispatched(&logits, &params, &mut rng_b, &mut scratch, dispatch);
                assert_eq!(ta, tb, "{dispatch:?} case {case}: token diverged ({params:?})");
                assert_eq!(
                    lpa.to_bits(),
                    lpb.to_bits(),
                    "{dispatch:?} case {case}: logprob bits diverged ({params:?})"
                );
                assert_eq!(
                    rng_a.next_u64(),
                    rng_b.next_u64(),
                    "{dispatch:?} case {case}: rng stream diverged"
                );
            }
        }
    }

    /// Adversarial rows at every dispatch level: vocab widths straddling
    /// the 4/8/16 SIMD lane widths (incl. vocab=1), all-ties rows,
    /// NaN-free positive subnormals, and rows with a `-inf` head mixture —
    /// all bit-identical to the reference oracle.
    #[test]
    fn adversarial_rows_match_reference_at_every_dispatch_level() {
        let widths = [1usize, 7, 8, 9, 15, 16, 17, 31, 33];
        let param_grid = [
            SamplingParams::default(),
            SamplingParams { temperature: 0.7, top_p: 0.9, top_k: -1 },
            SamplingParams { temperature: 1.0, top_p: 1.0, top_k: 5 },
            SamplingParams { temperature: 1.1, top_p: 0.85, top_k: 3 },
            SamplingParams::greedy(),
        ];
        for dispatch in SamplerDispatch::available() {
            let mut gen = Rng::new(4242);
            let mut scratch = SamplerScratch::new();
            let mut case = 0u64;
            for &n in &widths {
                for kind in 0..4 {
                    let logits: Vec<f32> = match kind {
                        // Plain random row.
                        0 => (0..n).map(|_| (gen.next_f64() * 8.0 - 4.0) as f32).collect(),
                        // All-ties: every mask/threshold path degenerates.
                        1 => vec![0.25f32; n],
                        // NaN-free positive subnormals (smallest f32s).
                        2 => (0..n)
                            .map(|_| f32::from_bits(1 + (gen.below(200)) as u32))
                            .collect(),
                        // -inf head mixture: every other entry is -inf
                        // (probs underflow to exact 0.0), at least one
                        // finite entry always present.
                        _ => (0..n)
                            .map(|i| if i % 2 == 1 { f32::NEG_INFINITY } else { 0.5 + i as f32 })
                            .collect(),
                    };
                    for params in &param_grid {
                        let mut rng_a = Rng::new(9000 + case);
                        let mut rng_b = rng_a.clone();
                        let (ta, lpa) = reference::sample_token_ref(&logits, params, &mut rng_a);
                        let (tb, lpb) = sample_token_dispatched(
                            &logits, params, &mut rng_b, &mut scratch, dispatch,
                        );
                        assert_eq!(
                            ta, tb,
                            "{dispatch:?} n={n} kind={kind} {params:?}: token diverged"
                        );
                        assert_eq!(
                            lpa.to_bits(),
                            lpb.to_bits(),
                            "{dispatch:?} n={n} kind={kind} {params:?}: logprob bits diverged"
                        );
                        assert_eq!(
                            rng_a.next_u64(),
                            rng_b.next_u64(),
                            "{dispatch:?} n={n} kind={kind} {params:?}: rng stream diverged"
                        );
                        case += 1;
                    }
                }
            }
        }
    }

    /// Nucleus-tail regression (the satellite fix): an all-`-inf`-except-
    /// one row concentrates all mass on the finite token — it must be
    /// picked with log-prob exactly 0.0 (ln 1), never -inf/NaN — at every
    /// dispatch level.
    #[test]
    fn all_neg_inf_except_one_picks_finite_token_with_zero_logprob() {
        for dispatch in SamplerDispatch::available() {
            let mut scratch = SamplerScratch::new();
            for n in [2usize, 9, 17, 48] {
                let mut logits = vec![f32::NEG_INFINITY; n];
                logits[n / 2] = 1.25;
                for params in [
                    SamplingParams::default(),
                    SamplingParams { temperature: 0.7, top_p: 0.9, top_k: -1 },
                ] {
                    let mut rng = Rng::new(31 + n as u64);
                    let (t, lp) =
                        sample_token_dispatched(&logits, &params, &mut rng, &mut scratch, dispatch);
                    assert_eq!(t as usize, n / 2, "{dispatch:?} n={n} {params:?}");
                    assert_eq!(lp, 0.0, "{dispatch:?} n={n} {params:?}: lp must be ln(1)");
                }
            }
        }
    }

    /// Fully-degenerate row (every logit `-inf` → every prob NaN): the
    /// quotient clamp keeps the log-prob finite (ln 1e-300 ≈ -690.78) and
    /// bit-identical to the reference, consuming exactly one RNG draw.
    #[test]
    fn fully_degenerate_row_yields_clamped_finite_logprob() {
        for dispatch in SamplerDispatch::available() {
            let mut scratch = SamplerScratch::new();
            let logits = vec![f32::NEG_INFINITY; 13];
            let params = SamplingParams::default();
            let mut rng_a = Rng::new(5);
            let mut rng_b = rng_a.clone();
            let (ta, lpa) = reference::sample_token_ref(&logits, &params, &mut rng_a);
            let (tb, lpb) =
                sample_token_dispatched(&logits, &params, &mut rng_b, &mut scratch, dispatch);
            assert_eq!(ta, tb, "{dispatch:?}");
            assert_eq!(lpa.to_bits(), lpb.to_bits(), "{dispatch:?}");
            assert!(lpb.is_finite(), "{dispatch:?}: lp {lpb} must be finite");
            assert!(
                (lpb as f64 - 1e-300f64.ln()).abs() < 1e-3,
                "{dispatch:?}: lp {lpb} should sit at the clamp floor"
            );
            assert_eq!(rng_a.next_u64(), rng_b.next_u64(), "{dispatch:?}: rng diverged");
        }
    }

    /// Scratch capacity stabilizes after the first call at the max vocab —
    /// later calls never regrow it (the alloc-free contract's mechanism) —
    /// on every dispatch arm.
    #[test]
    fn scratch_capacity_is_stable_after_warmup() {
        for dispatch in SamplerDispatch::available() {
            let mut rng = Rng::new(6);
            let mut scratch = SamplerScratch::new();
            let logits: Vec<f32> = (0..48).map(|i| (i % 7) as f32 * 0.4).collect();
            let p = SamplingParams { temperature: 1.0, top_p: 0.9, top_k: 8 };
            sample_token_dispatched(&logits, &p, &mut rng, &mut scratch, dispatch);
            let cap = scratch.capacity();
            assert!(cap >= 48);
            for _ in 0..200 {
                sample_token_dispatched(&logits, &p, &mut rng, &mut scratch, dispatch);
                assert_eq!(
                    scratch.capacity(),
                    cap,
                    "{dispatch:?}: scratch regrew in steady state"
                );
            }
        }
    }
}
