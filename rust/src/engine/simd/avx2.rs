//! 256-bit AVX2 arms of the sampler kernels. Bit-identical to
//! [`super::scalar`] for NaN-free logit rows — see the module docs in
//! [`super`] for the reordering argument behind each kernel.
//!
//! Every function here is `unsafe fn` + `#[target_feature(enable =
//! "avx2")]`: the caller ([`super`]'s dispatch wrappers) guarantees the
//! feature is present (checked once at [`super::SamplerDispatch::detect`]
//! time).

use std::arch::x86_64::*;

/// Max over the row: lane-wise running max, then a sequential `f32::max`
/// fold over the 8 lanes and the ragged tail. Exact for NaN-free rows
/// because `max` is associative and commutative there; a `-0.0`/`+0.0`
/// ambiguity only ever feeds a subtraction with identical results.
#[target_feature(enable = "avx2")]
pub(super) unsafe fn max_f32(xs: &[f32]) -> f32 {
    let n = xs.len();
    let mut acc = f32::NEG_INFINITY;
    let mut i = 0;
    if n >= 8 {
        unsafe {
            let mut v = _mm256_loadu_ps(xs.as_ptr());
            i = 8;
            while i + 8 <= n {
                v = _mm256_max_ps(v, _mm256_loadu_ps(xs.as_ptr().add(i)));
                i += 8;
            }
            let mut lanes = [0f32; 8];
            _mm256_storeu_ps(lanes.as_mut_ptr(), v);
            for &l in &lanes {
                acc = acc.max(l);
            }
        }
    }
    for &x in &xs[i..] {
        acc = acc.max(x);
    }
    acc
}

/// First index of the maximum: vector max, then an 8-wide equality scan
/// whose first hit is the answer — reproducing the scalar strict-`>`
/// first-occurrence rule exactly (an all-`-inf` row matches at index 0).
#[target_feature(enable = "avx2")]
pub(super) unsafe fn argmax_f32(xs: &[f32]) -> usize {
    let m = unsafe { max_f32(xs) };
    let mut i = 0;
    unsafe {
        let vm = _mm256_set1_ps(m);
        while i + 8 <= xs.len() {
            let v = _mm256_loadu_ps(xs.as_ptr().add(i));
            let eq = _mm256_cmp_ps::<_CMP_EQ_OQ>(v, vm);
            let mask = _mm256_movemask_ps(eq);
            if mask != 0 {
                return i + mask.trailing_zeros() as usize;
            }
            i += 8;
        }
    }
    for (j, &x) in xs[i..].iter().enumerate() {
        if x == m {
            return i + j;
        }
    }
    0
}

/// Softmax numerators: the f32→f64 convert / subtract / scale argument
/// pipeline runs 4-wide (purely elementwise IEEE ops — exact), then the
/// `exp` runs scalar per element in place (libm bit-identity).
#[target_feature(enable = "avx2")]
pub(super) unsafe fn exp_scaled(logits: &[f32], maxl: f64, inv_t: f64, out: &mut Vec<f64>) {
    let n = logits.len();
    out.clear();
    out.reserve(n);
    unsafe {
        let vmax = _mm256_set1_pd(maxl);
        let vt = _mm256_set1_pd(inv_t);
        let p = out.as_mut_ptr();
        let mut i = 0;
        while i + 4 <= n {
            let f = _mm_loadu_ps(logits.as_ptr().add(i));
            let d = _mm256_cvtps_pd(f);
            let a = _mm256_mul_pd(_mm256_sub_pd(d, vmax), vt);
            _mm256_storeu_pd(p.add(i), a);
            i += 4;
        }
        while i < n {
            *p.add(i) = (*logits.get_unchecked(i) as f64 - maxl) * inv_t;
            i += 1;
        }
        out.set_len(n);
    }
    for v in out.iter_mut() {
        *v = v.exp();
    }
}

/// Entries strictly greater than `thresh`: ordered-quiet GT compare +
/// movemask popcount (NaN compares false, matching the scalar filter).
#[target_feature(enable = "avx2")]
pub(super) unsafe fn count_greater(probs: &[f64], thresh: f64) -> usize {
    let n = probs.len();
    let mut count = 0usize;
    let mut i = 0;
    unsafe {
        let vt = _mm256_set1_pd(thresh);
        while i + 4 <= n {
            let v = _mm256_loadu_pd(probs.as_ptr().add(i));
            let gt = _mm256_cmp_pd::<_CMP_GT_OQ>(v, vt);
            count += _mm256_movemask_pd(gt).count_ones() as usize;
            i += 4;
        }
    }
    count + probs[i..].iter().filter(|&&p| p > thresh).count()
}

/// Exact-k masking in two passes: a 4-wide GE keep-mask (`and` with the
/// mask leaves kept bits untouched and writes `+0.0` elsewhere — the same
/// `0.0` the scalar arm stores; NaN fails GE and is zeroed, also matching
/// scalar), then a scalar index-order pass applying the tie quota to
/// entries equal to the threshold. Entries zeroed by the first pass can
/// never alias the threshold (`0.0 == thresh` only when `thresh == 0.0`,
/// and then the first pass zeroes nothing).
#[target_feature(enable = "avx2")]
pub(super) unsafe fn mask_top_k(probs: &mut [f64], thresh: f64, mut tie_quota: usize) {
    let n = probs.len();
    let mut i = 0;
    unsafe {
        let vt = _mm256_set1_pd(thresh);
        while i + 4 <= n {
            let p = probs.as_mut_ptr().add(i);
            let v = _mm256_loadu_pd(p);
            let keep = _mm256_cmp_pd::<_CMP_GE_OQ>(v, vt);
            _mm256_storeu_pd(p, _mm256_and_pd(v, keep));
            i += 4;
        }
    }
    for p in probs[i..].iter_mut() {
        if !(*p >= thresh) {
            *p = 0.0;
        }
    }
    for p in probs.iter_mut() {
        if *p == thresh {
            if tie_quota > 0 {
                tie_quota -= 1;
            } else {
                *p = 0.0;
            }
        }
    }
}

/// Nucleus cut: gather the next four ranked probabilities, divide by
/// `total` in one vector op (elementwise, exact), then feed the running
/// cumulative sum scalar-ordered with the same early exit as the scalar
/// arm.
#[target_feature(enable = "avx2")]
pub(super) unsafe fn nucleus_cut(probs: &[f64], idx: &[u32], total: f64, top_p: f64) -> usize {
    let n = idx.len();
    let mut cum = 0.0f64;
    let mut rank = 0usize;
    let mut q = [0f64; 4];
    unsafe {
        let vtot = _mm256_set1_pd(total);
        while rank + 4 <= n {
            let g = _mm256_set_pd(
                *probs.get_unchecked(*idx.get_unchecked(rank + 3) as usize),
                *probs.get_unchecked(*idx.get_unchecked(rank + 2) as usize),
                *probs.get_unchecked(*idx.get_unchecked(rank + 1) as usize),
                *probs.get_unchecked(*idx.get_unchecked(rank) as usize),
            );
            let d = _mm256_div_pd(g, vtot);
            _mm256_storeu_pd(q.as_mut_ptr(), d);
            for (j, &qq) in q.iter().enumerate() {
                cum += qq;
                if cum >= top_p {
                    return rank + j + 1;
                }
            }
            rank += 4;
        }
    }
    for r in rank..n {
        cum += probs[idx[r] as usize] / total;
        if cum >= top_p {
            return r + 1;
        }
    }
    n
}
