//! Portable scalar sampler kernels — verbatim the loops the pre-SIMD
//! sampler ran, factored out so every vector arm has a reference to be
//! differentially fuzzed against (and so non-x86_64 targets keep working
//! untouched). Semantics notes live on each kernel; the bit-identity
//! contract is documented in [`super`].

/// Max over the row via the sequential `f32::max` fold the sampler always
/// used. `-inf` for an all-`-inf` row; NaN entries are ignored (but the
/// dispatched path requires NaN-free logits — see [`super`]).
pub fn max_f32(xs: &[f32]) -> f32 {
    xs.iter().cloned().fold(f32::NEG_INFINITY, f32::max)
}

/// First index of the maximum: strict-`>` scan, lowest index wins ties.
/// Index 0 for an all-`-inf` row (nothing beats the `-inf` seed).
pub fn argmax_f32(xs: &[f32]) -> usize {
    let mut bi = 0;
    let mut bv = f32::NEG_INFINITY;
    for (i, &x) in xs.iter().enumerate() {
        if x > bv {
            bi = i;
            bv = x;
        }
    }
    bi
}

/// Fill `out` with the stable-softmax numerators
/// `exp((l as f64 - maxl) * inv_t)`, clearing it first.
pub fn exp_scaled(logits: &[f32], maxl: f64, inv_t: f64, out: &mut Vec<f64>) {
    out.clear();
    out.extend(logits.iter().map(|&l| ((l as f64 - maxl) * inv_t).exp()));
}

/// Entries strictly greater than `thresh` (sizes the top-k tie quota).
pub fn count_greater(probs: &[f64], thresh: f64) -> usize {
    probs.iter().filter(|&&p| p > thresh).count()
}

/// Exact-k top-k masking: keep entries above `thresh`, keep the first
/// `tie_quota` entries equal to it in index order, zero everything else
/// (including NaN entries — neither comparison matches them).
pub fn mask_top_k(probs: &mut [f64], thresh: f64, mut tie_quota: usize) {
    for p in probs.iter_mut() {
        if *p > thresh {
            continue;
        }
        if *p == thresh && tie_quota > 0 {
            tie_quota -= 1;
            continue;
        }
        *p = 0.0;
    }
}

/// Nucleus cut: accumulate `probs[idx[rank]] / total` over the ranked
/// index array until the cumulative mass reaches `top_p`; returns the
/// number of leading ranks to keep (`idx.len()` when the mass never gets
/// there — then nothing is cut).
pub fn nucleus_cut(probs: &[f64], idx: &[u32], total: f64, top_p: f64) -> usize {
    let mut cum = 0.0;
    for (rank, &i) in idx.iter().enumerate() {
        cum += probs[i as usize] / total;
        if cum >= top_p {
            return rank + 1;
        }
    }
    idx.len()
}
