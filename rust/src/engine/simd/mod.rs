//! Runtime-dispatched SIMD kernels for the sampler hot path.
//!
//! The decode loop samples one token per active slot per step; at steady
//! state the per-token cost is dominated by the softmax/top-k/top-p walk
//! over the full vocab row ([`super::sampler`]). This module vectorizes
//! the data-parallel pieces of that walk — the max reduction, argmax, the
//! exp *argument* pipeline (convert / subtract / scale), top-k threshold
//! masking, and the nucleus gather-divide — behind a ladder of arms
//! selected once per engine at construction time:
//!
//! - [`SamplerDispatch::Scalar`] — the portable kernels in [`scalar`],
//!   verbatim the pre-SIMD sampler loops. Always available; the reference
//!   arm every other arm is differentially fuzzed against.
//! - [`SamplerDispatch::Avx2`] — 256-bit arms (4×f64 / 8×f32).
//! - [`SamplerDispatch::Avx512`] — 512-bit arms (8×f64 / 16×f32),
//!   requiring `avx512f`.
//!
//! # Bit-identity contract
//!
//! Every arm produces **bit-identical** results to the scalar arm for
//! NaN-free logit rows: same token picks, same log-prob bits, same RNG
//! consumption. This is load-bearing — the engine goldens
//! (`tests/golden_determinism.rs`, `tests/rollout_golden.rs`, …) pin
//! log-prob streams, and CI runs them at whatever dispatch level the
//! runner supports. The contract is kept by construction rather than by
//! tolerance:
//!
//! - only *exactly reorderable* reductions are vectorized: `max` is
//!   associative (±0.0 ambiguity is harmless — the max only feeds a
//!   subtraction with identical results either way), comparisons and
//!   masking are exact, and the f32→f64 convert / subtract / multiply
//!   pipeline is purely elementwise IEEE arithmetic;
//! - `f64::exp` stays scalar per element (no vector exp matches libm
//!   bit-for-bit) — the SIMD win there is the vectorized argument
//!   pipeline, not the transcendental;
//! - every *sequentially rounded* chain — the two `probs` totals and the
//!   nucleus cumulative walk — stays scalar left-to-right in all arms;
//!   the nucleus arm vectorizes only the per-rank `probs[idx]/total`
//!   gather-divide (elementwise, exact) feeding that walk.
//!
//! The contract is enforced by the 500-case differential fuzz in
//! `sampler.rs`, which runs once per [`SamplerDispatch::available`] level,
//! and by the `scripts/ci.sh --simd` matrix leg (native codegen and
//! forced-scalar `COPRIS_SIMD=scalar`).

pub mod scalar;

#[cfg(target_arch = "x86_64")]
mod avx2;
#[cfg(target_arch = "x86_64")]
mod avx512;

/// Instruction-set arm the sampler hot path runs on. Detected once per
/// engine ([`SamplerDispatch::detect`]) and recorded in every
/// [`super::StepTrace`] so bench rows and metrics know which path ran.
///
/// Variant order is the capability ladder (`Scalar < Avx2 < Avx512`);
/// [`Ord`] is used to degrade an env-requested level to the best the
/// machine actually supports. Construct values only via [`detect`],
/// [`from_request`] or [`available`] — the vector arms assume their CPU
/// feature is present.
///
/// [`detect`]: SamplerDispatch::detect
/// [`from_request`]: SamplerDispatch::from_request
/// [`available`]: SamplerDispatch::available
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, PartialOrd, Ord)]
pub enum SamplerDispatch {
    /// Portable scalar kernels — the verbatim reference arm.
    #[default]
    Scalar,
    /// 256-bit AVX2 arms.
    Avx2,
    /// 512-bit AVX-512F arms.
    Avx512,
}

impl SamplerDispatch {
    /// Stable lowercase name (`"scalar"` / `"avx2"` / `"avx512"`) — the
    /// value carried through StepTrace → RolloutStats → JSONL.
    pub fn name(self) -> &'static str {
        match self {
            SamplerDispatch::Scalar => "scalar",
            SamplerDispatch::Avx2 => "avx2",
            SamplerDispatch::Avx512 => "avx512",
        }
    }

    /// The widest arm this machine supports (`is_x86_feature_detected!`;
    /// scalar on non-x86_64 targets).
    pub fn best_available() -> SamplerDispatch {
        #[cfg(target_arch = "x86_64")]
        {
            if is_x86_feature_detected!("avx512f") {
                return SamplerDispatch::Avx512;
            }
            if is_x86_feature_detected!("avx2") {
                return SamplerDispatch::Avx2;
            }
        }
        SamplerDispatch::Scalar
    }

    /// Resolve an explicit request against the machine's capability.
    /// `"scalar"` forces the reference arm; `"avx2"`/`"avx512"` request
    /// that arm but degrade to the best actually available; anything else
    /// (including `None`) auto-selects [`Self::best_available`]. Pure —
    /// no env access — so tests can exercise every mapping without racy
    /// process-wide env mutation.
    pub fn from_request(req: Option<&str>, best: SamplerDispatch) -> SamplerDispatch {
        match req.map(str::trim) {
            Some("scalar") => SamplerDispatch::Scalar,
            Some("avx2") => SamplerDispatch::Avx2.min(best),
            Some("avx512") => SamplerDispatch::Avx512.min(best),
            _ => best,
        }
    }

    /// Detect the dispatch level for this process: the `COPRIS_SIMD` env
    /// override (see [`Self::from_request`]) resolved against
    /// [`Self::best_available`]. Called once per engine at construction.
    pub fn detect() -> SamplerDispatch {
        Self::from_request(
            std::env::var("COPRIS_SIMD").ok().as_deref(),
            Self::best_available(),
        )
    }

    /// Every arm this machine can run, narrowest first (always contains
    /// [`SamplerDispatch::Scalar`]) — the fuzz harness runs the
    /// differential oracle once per entry.
    pub fn available() -> Vec<SamplerDispatch> {
        let best = Self::best_available();
        [SamplerDispatch::Scalar, SamplerDispatch::Avx2, SamplerDispatch::Avx512]
            .into_iter()
            .filter(|&d| d <= best)
            .collect()
    }
}

/// Max over a NaN-free f32 row (`-inf` for an all-`-inf` row, matching the
/// scalar fold's behaviour).
pub fn max_f32(d: SamplerDispatch, xs: &[f32]) -> f32 {
    match d {
        SamplerDispatch::Scalar => scalar::max_f32(xs),
        #[cfg(target_arch = "x86_64")]
        SamplerDispatch::Avx2 => {
            debug_assert!(is_x86_feature_detected!("avx2"));
            unsafe { avx2::max_f32(xs) }
        }
        #[cfg(target_arch = "x86_64")]
        SamplerDispatch::Avx512 => {
            debug_assert!(is_x86_feature_detected!("avx512f"));
            unsafe { avx512::max_f32(xs) }
        }
        #[cfg(not(target_arch = "x86_64"))]
        _ => scalar::max_f32(xs),
    }
}

/// First index of the maximum of a NaN-free f32 row (greedy decoding);
/// ties resolve to the lowest index, exactly like the scalar strict-`>`
/// scan.
pub fn argmax_f32(d: SamplerDispatch, xs: &[f32]) -> usize {
    match d {
        SamplerDispatch::Scalar => scalar::argmax_f32(xs),
        #[cfg(target_arch = "x86_64")]
        SamplerDispatch::Avx2 => {
            debug_assert!(is_x86_feature_detected!("avx2"));
            unsafe { avx2::argmax_f32(xs) }
        }
        #[cfg(target_arch = "x86_64")]
        SamplerDispatch::Avx512 => {
            debug_assert!(is_x86_feature_detected!("avx512f"));
            unsafe { avx512::argmax_f32(xs) }
        }
        #[cfg(not(target_arch = "x86_64"))]
        _ => scalar::argmax_f32(xs),
    }
}

/// Fill `out` with `exp((l - maxl) * inv_t)` per logit — the stable
/// softmax numerators. Vector arms batch the convert/subtract/multiply
/// argument pipeline; the `exp` itself is scalar libm in every arm (the
/// bit-identity contract).
pub fn exp_scaled(d: SamplerDispatch, logits: &[f32], maxl: f64, inv_t: f64, out: &mut Vec<f64>) {
    match d {
        SamplerDispatch::Scalar => scalar::exp_scaled(logits, maxl, inv_t, out),
        #[cfg(target_arch = "x86_64")]
        SamplerDispatch::Avx2 => {
            debug_assert!(is_x86_feature_detected!("avx2"));
            unsafe { avx2::exp_scaled(logits, maxl, inv_t, out) }
        }
        #[cfg(target_arch = "x86_64")]
        SamplerDispatch::Avx512 => {
            debug_assert!(is_x86_feature_detected!("avx512f"));
            unsafe { avx512::exp_scaled(logits, maxl, inv_t, out) }
        }
        #[cfg(not(target_arch = "x86_64"))]
        _ => scalar::exp_scaled(logits, maxl, inv_t, out),
    }
}

/// Count of entries strictly greater than `thresh` (top-k tie sizing).
pub fn count_greater(d: SamplerDispatch, probs: &[f64], thresh: f64) -> usize {
    match d {
        SamplerDispatch::Scalar => scalar::count_greater(probs, thresh),
        #[cfg(target_arch = "x86_64")]
        SamplerDispatch::Avx2 => {
            debug_assert!(is_x86_feature_detected!("avx2"));
            unsafe { avx2::count_greater(probs, thresh) }
        }
        #[cfg(target_arch = "x86_64")]
        SamplerDispatch::Avx512 => {
            debug_assert!(is_x86_feature_detected!("avx512f"));
            unsafe { avx512::count_greater(probs, thresh) }
        }
        #[cfg(not(target_arch = "x86_64"))]
        _ => scalar::count_greater(probs, thresh),
    }
}

/// Top-k threshold masking: zero every entry below `thresh`, keep entries
/// above it, and keep the first `tie_quota` entries equal to it (index
/// order) — the exact-k tie rule of the scalar arm.
pub fn mask_top_k(d: SamplerDispatch, probs: &mut [f64], thresh: f64, tie_quota: usize) {
    match d {
        SamplerDispatch::Scalar => scalar::mask_top_k(probs, thresh, tie_quota),
        #[cfg(target_arch = "x86_64")]
        SamplerDispatch::Avx2 => {
            debug_assert!(is_x86_feature_detected!("avx2"));
            unsafe { avx2::mask_top_k(probs, thresh, tie_quota) }
        }
        #[cfg(target_arch = "x86_64")]
        SamplerDispatch::Avx512 => {
            debug_assert!(is_x86_feature_detected!("avx512f"));
            unsafe { avx512::mask_top_k(probs, thresh, tie_quota) }
        }
        #[cfg(not(target_arch = "x86_64"))]
        _ => scalar::mask_top_k(probs, thresh, tie_quota),
    }
}

/// Nucleus cut: walk the ranked index array accumulating
/// `probs[idx[rank]] / total` until the cumulative mass reaches `top_p`;
/// returns the first rank count to KEEP (`idx.len()` when the mass never
/// reaches `top_p`). Vector arms batch the gather-divide; the running sum
/// stays scalar-ordered (bit-identity).
pub fn nucleus_cut(d: SamplerDispatch, probs: &[f64], idx: &[u32], total: f64, top_p: f64) -> usize {
    match d {
        SamplerDispatch::Scalar => scalar::nucleus_cut(probs, idx, total, top_p),
        #[cfg(target_arch = "x86_64")]
        SamplerDispatch::Avx2 => {
            debug_assert!(is_x86_feature_detected!("avx2"));
            unsafe { avx2::nucleus_cut(probs, idx, total, top_p) }
        }
        #[cfg(target_arch = "x86_64")]
        SamplerDispatch::Avx512 => {
            debug_assert!(is_x86_feature_detected!("avx512f"));
            unsafe { avx512::nucleus_cut(probs, idx, total, top_p) }
        }
        #[cfg(not(target_arch = "x86_64"))]
        _ => scalar::nucleus_cut(probs, idx, total, top_p),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ladder_order_and_names() {
        assert!(SamplerDispatch::Scalar < SamplerDispatch::Avx2);
        assert!(SamplerDispatch::Avx2 < SamplerDispatch::Avx512);
        assert_eq!(SamplerDispatch::Scalar.name(), "scalar");
        assert_eq!(SamplerDispatch::Avx2.name(), "avx2");
        assert_eq!(SamplerDispatch::Avx512.name(), "avx512");
    }

    #[test]
    fn from_request_honors_force_and_degrades() {
        use SamplerDispatch::*;
        // Forced scalar wins regardless of capability.
        assert_eq!(SamplerDispatch::from_request(Some("scalar"), Avx512), Scalar);
        assert_eq!(SamplerDispatch::from_request(Some("scalar"), Scalar), Scalar);
        // Requests degrade to the best available, never exceed it.
        assert_eq!(SamplerDispatch::from_request(Some("avx512"), Avx2), Avx2);
        assert_eq!(SamplerDispatch::from_request(Some("avx512"), Avx512), Avx512);
        assert_eq!(SamplerDispatch::from_request(Some("avx2"), Scalar), Scalar);
        assert_eq!(SamplerDispatch::from_request(Some("avx2"), Avx512), Avx2);
        // Whitespace tolerated; unknown / absent = auto.
        assert_eq!(SamplerDispatch::from_request(Some(" scalar "), Avx2), Scalar);
        assert_eq!(SamplerDispatch::from_request(Some("neon"), Avx2), Avx2);
        assert_eq!(SamplerDispatch::from_request(None, Avx512), Avx512);
    }

    #[test]
    fn available_always_contains_scalar_and_is_prefix_of_ladder() {
        let avail = SamplerDispatch::available();
        assert_eq!(avail[0], SamplerDispatch::Scalar);
        let best = SamplerDispatch::best_available();
        assert!(avail.iter().all(|&d| d <= best));
        assert!(avail.contains(&best));
        // The list is the full ladder prefix up to `best`.
        assert_eq!(avail.len(), avail.iter().filter(|&&d| d <= best).count());
    }

    /// Every dispatched kernel agrees bitwise with the scalar arm on a
    /// fixed golden row — the lane-reduction-order pin for the vector
    /// arms (the full 500-case differential fuzz lives in `sampler.rs`).
    #[test]
    fn kernels_match_scalar_bitwise_on_golden_rows() {
        // 19 entries: exercises full vector blocks plus ragged tails at
        // both 4/8 (f64) and 8/16 (f32) widths.
        let logits: Vec<f32> = (0..19)
            .map(|i| ((i * 37 % 19) as f32 - 9.0) * 0.37 + if i % 5 == 0 { 1.5 } else { 0.0 })
            .collect();
        let maxl = scalar::max_f32(&logits) as f64;
        let mut want = Vec::new();
        scalar::exp_scaled(&logits, maxl, 1.0 / 0.85, &mut want);
        let thresh = {
            let mut s = want.clone();
            s.sort_by(|a, b| b.partial_cmp(a).unwrap());
            s[6]
        };
        let mut idx: Vec<u32> = (0..want.len() as u32).collect();
        idx.sort_unstable_by(|&a, &b| {
            want[b as usize].partial_cmp(&want[a as usize]).unwrap().then(a.cmp(&b))
        });
        let total: f64 = want.iter().sum();
        for d in SamplerDispatch::available() {
            assert_eq!(max_f32(d, &logits).to_bits(), (maxl as f32).to_bits(), "{d:?} max");
            assert_eq!(argmax_f32(d, &logits), scalar::argmax_f32(&logits), "{d:?} argmax");
            let mut got = Vec::new();
            exp_scaled(d, &logits, maxl, 1.0 / 0.85, &mut got);
            let wb: Vec<u64> = want.iter().map(|v| v.to_bits()).collect();
            let gb: Vec<u64> = got.iter().map(|v| v.to_bits()).collect();
            assert_eq!(wb, gb, "{d:?} exp_scaled");
            assert_eq!(
                count_greater(d, &want, thresh),
                scalar::count_greater(&want, thresh),
                "{d:?} count_greater"
            );
            let mut a = want.clone();
            let mut b = want.clone();
            scalar::mask_top_k(&mut a, thresh, 1);
            mask_top_k(d, &mut b, thresh, 1);
            let ab: Vec<u64> = a.iter().map(|v| v.to_bits()).collect();
            let bb: Vec<u64> = b.iter().map(|v| v.to_bits()).collect();
            assert_eq!(ab, bb, "{d:?} mask_top_k");
            for &p in &[0.1, 0.5, 0.9, 0.999, 1.5] {
                assert_eq!(
                    nucleus_cut(d, &want, &idx, total, p),
                    scalar::nucleus_cut(&want, &idx, total, p),
                    "{d:?} nucleus_cut top_p={p}"
                );
            }
        }
    }

    #[test]
    fn argmax_all_neg_inf_row_picks_index_zero() {
        let row = [f32::NEG_INFINITY; 11];
        for d in SamplerDispatch::available() {
            assert_eq!(argmax_f32(d, &row), 0, "{d:?}");
            assert_eq!(max_f32(d, &row), f32::NEG_INFINITY, "{d:?}");
        }
    }
}
