//! 512-bit AVX-512F arms of the sampler kernels. Bit-identical to
//! [`super::scalar`] for NaN-free logit rows — same reordering arguments
//! as the AVX2 arms ([`super::avx2`]), at twice the lane width and with
//! the compare results landing in mask registers instead of vector masks.
//!
//! Every function here is `unsafe fn` + `#[target_feature(enable =
//! "avx512f")]`: the caller ([`super`]'s dispatch wrappers) guarantees
//! the feature is present.

use std::arch::x86_64::*;

/// Max over the row: 16-wide running max, sequential `f32::max` fold over
/// the lanes and the ragged tail (exact for NaN-free rows).
#[target_feature(enable = "avx512f")]
pub(super) unsafe fn max_f32(xs: &[f32]) -> f32 {
    let n = xs.len();
    let mut acc = f32::NEG_INFINITY;
    let mut i = 0;
    if n >= 16 {
        unsafe {
            let mut v = _mm512_loadu_ps(xs.as_ptr());
            i = 16;
            while i + 16 <= n {
                v = _mm512_max_ps(v, _mm512_loadu_ps(xs.as_ptr().add(i)));
                i += 16;
            }
            let mut lanes = [0f32; 16];
            _mm512_storeu_ps(lanes.as_mut_ptr(), v);
            for &l in &lanes {
                acc = acc.max(l);
            }
        }
    }
    for &x in &xs[i..] {
        acc = acc.max(x);
    }
    acc
}

/// First index of the maximum via a 16-wide equality scan (first mask hit
/// wins) — the scalar first-occurrence rule exactly.
#[target_feature(enable = "avx512f")]
pub(super) unsafe fn argmax_f32(xs: &[f32]) -> usize {
    let m = unsafe { max_f32(xs) };
    let mut i = 0;
    unsafe {
        let vm = _mm512_set1_ps(m);
        while i + 16 <= xs.len() {
            let v = _mm512_loadu_ps(xs.as_ptr().add(i));
            let eq: __mmask16 = _mm512_cmp_ps_mask::<_CMP_EQ_OQ>(v, vm);
            if eq != 0 {
                return i + eq.trailing_zeros() as usize;
            }
            i += 16;
        }
    }
    for (j, &x) in xs[i..].iter().enumerate() {
        if x == m {
            return i + j;
        }
    }
    0
}

/// Softmax numerators: 8-wide f32→f64 convert / subtract / scale (exact
/// elementwise IEEE ops), scalar libm `exp` in place.
#[target_feature(enable = "avx512f")]
pub(super) unsafe fn exp_scaled(logits: &[f32], maxl: f64, inv_t: f64, out: &mut Vec<f64>) {
    let n = logits.len();
    out.clear();
    out.reserve(n);
    unsafe {
        let vmax = _mm512_set1_pd(maxl);
        let vt = _mm512_set1_pd(inv_t);
        let p = out.as_mut_ptr();
        let mut i = 0;
        while i + 8 <= n {
            let f = _mm256_loadu_ps(logits.as_ptr().add(i));
            let d = _mm512_cvtps_pd(f);
            let a = _mm512_mul_pd(_mm512_sub_pd(d, vmax), vt);
            _mm512_storeu_pd(p.add(i), a);
            i += 8;
        }
        while i < n {
            *p.add(i) = (*logits.get_unchecked(i) as f64 - maxl) * inv_t;
            i += 1;
        }
        out.set_len(n);
    }
    for v in out.iter_mut() {
        *v = v.exp();
    }
}

/// Entries strictly greater than `thresh`: GT compare into a mask
/// register, popcount.
#[target_feature(enable = "avx512f")]
pub(super) unsafe fn count_greater(probs: &[f64], thresh: f64) -> usize {
    let n = probs.len();
    let mut count = 0usize;
    let mut i = 0;
    unsafe {
        let vt = _mm512_set1_pd(thresh);
        while i + 8 <= n {
            let v = _mm512_loadu_pd(probs.as_ptr().add(i));
            let gt: __mmask8 = _mm512_cmp_pd_mask::<_CMP_GT_OQ>(v, vt);
            count += gt.count_ones() as usize;
            i += 8;
        }
    }
    count + probs[i..].iter().filter(|&&p| p > thresh).count()
}

/// Exact-k masking: 8-wide GE keep-mask (`maskz_mov` writes `+0.0` to
/// dropped lanes — the scalar arm's `0.0`; NaN fails GE), then the scalar
/// index-order tie-quota pass. See the AVX2 arm for why the two passes
/// compose exactly.
#[target_feature(enable = "avx512f")]
pub(super) unsafe fn mask_top_k(probs: &mut [f64], thresh: f64, mut tie_quota: usize) {
    let n = probs.len();
    let mut i = 0;
    unsafe {
        let vt = _mm512_set1_pd(thresh);
        while i + 8 <= n {
            let p = probs.as_mut_ptr().add(i);
            let v = _mm512_loadu_pd(p);
            let keep: __mmask8 = _mm512_cmp_pd_mask::<_CMP_GE_OQ>(v, vt);
            _mm512_storeu_pd(p, _mm512_maskz_mov_pd(keep, v));
            i += 8;
        }
    }
    for p in probs[i..].iter_mut() {
        if !(*p >= thresh) {
            *p = 0.0;
        }
    }
    for p in probs.iter_mut() {
        if *p == thresh {
            if tie_quota > 0 {
                tie_quota -= 1;
            } else {
                *p = 0.0;
            }
        }
    }
}

/// Nucleus cut: 8-wide gather-divide feeding the scalar-ordered running
/// sum with the scalar arm's early exit.
#[target_feature(enable = "avx512f")]
pub(super) unsafe fn nucleus_cut(probs: &[f64], idx: &[u32], total: f64, top_p: f64) -> usize {
    let n = idx.len();
    let mut cum = 0.0f64;
    let mut rank = 0usize;
    let mut q = [0f64; 8];
    unsafe {
        let vtot = _mm512_set1_pd(total);
        while rank + 8 <= n {
            let g = _mm512_set_pd(
                *probs.get_unchecked(*idx.get_unchecked(rank + 7) as usize),
                *probs.get_unchecked(*idx.get_unchecked(rank + 6) as usize),
                *probs.get_unchecked(*idx.get_unchecked(rank + 5) as usize),
                *probs.get_unchecked(*idx.get_unchecked(rank + 4) as usize),
                *probs.get_unchecked(*idx.get_unchecked(rank + 3) as usize),
                *probs.get_unchecked(*idx.get_unchecked(rank + 2) as usize),
                *probs.get_unchecked(*idx.get_unchecked(rank + 1) as usize),
                *probs.get_unchecked(*idx.get_unchecked(rank) as usize),
            );
            let d = _mm512_div_pd(g, vtot);
            _mm512_storeu_pd(q.as_mut_ptr(), d);
            for (j, &qq) in q.iter().enumerate() {
                cum += qq;
                if cum >= top_p {
                    return rank + j + 1;
                }
            }
            rank += 8;
        }
    }
    for r in rank..n {
        cum += probs[idx[r] as usize] / total;
        if cum >= top_p {
            return r + 1;
        }
    }
    n
}
