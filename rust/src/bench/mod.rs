//! Bench harness (criterion is not in the vendored crate set): warmup +
//! timed iterations with summary stats, plus aligned table rendering for
//! the paper-style outputs every `rust/benches/*.rs` target prints.

use std::time::Instant;

use crate::util::stats::Summary;

/// Time `f` for `iters` iterations after `warmup` untimed runs.
pub fn time_fn<T>(warmup: usize, iters: usize, mut f: impl FnMut() -> T) -> Summary {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        std::hint::black_box(f());
        samples.push(t0.elapsed().as_secs_f64());
    }
    Summary::of(&samples)
}

/// Render an aligned ASCII table (paper-style rows).
pub fn render_table(headers: &[&str], rows: &[Vec<String>]) -> String {
    let ncol = headers.len();
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate().take(ncol) {
            widths[i] = widths[i].max(cell.len());
        }
    }
    let mut out = String::new();
    let fmt_row = |cells: &[String], widths: &[usize]| -> String {
        let mut line = String::from("|");
        for (i, c) in cells.iter().enumerate() {
            line.push_str(&format!(" {:<w$} |", c, w = widths[i]));
        }
        line.push('\n');
        line
    };
    out.push_str(&fmt_row(
        &headers.iter().map(|s| s.to_string()).collect::<Vec<_>>(),
        &widths,
    ));
    let mut sep = String::from("|");
    for w in &widths {
        sep.push_str(&format!("{}|", "-".repeat(w + 2)));
    }
    sep.push('\n');
    out.push_str(&sep);
    for row in rows {
        out.push_str(&fmt_row(row, &widths));
    }
    out
}

/// Format seconds compactly (µs/ms/s).
pub fn fmt_secs(s: f64) -> String {
    if s < 1e-3 {
        format!("{:.1}µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{:.2}s", s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_fn_counts_iters() {
        let mut calls = 0usize;
        let s = time_fn(2, 5, || calls += 1);
        assert_eq!(calls, 7);
        assert_eq!(s.n, 5);
        assert!(s.mean >= 0.0);
    }

    #[test]
    fn table_is_aligned() {
        let t = render_table(
            &["name", "value"],
            &[
                vec!["a".into(), "1".into()],
                vec!["longer-name".into(), "2".into()],
            ],
        );
        let lines: Vec<&str> = t.lines().collect();
        assert_eq!(lines.len(), 4);
        let w = lines[0].len();
        assert!(lines.iter().all(|l| l.len() == w), "{t}");
    }

    #[test]
    fn fmt_secs_ranges() {
        assert!(fmt_secs(5e-6).ends_with("µs"));
        assert!(fmt_secs(5e-3).ends_with("ms"));
        assert!(fmt_secs(2.0).ends_with('s'));
    }
}
