//! Bench harness (criterion is not in the vendored crate set): warmup +
//! timed iterations with summary stats, plus aligned table rendering for
//! the paper-style outputs every `rust/benches/*.rs` target prints.

use std::time::Instant;

use crate::util::stats::Summary;

/// Time `f` for `iters` iterations after `warmup` untimed runs.
pub fn time_fn<T>(warmup: usize, iters: usize, mut f: impl FnMut() -> T) -> Summary {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        std::hint::black_box(f());
        samples.push(t0.elapsed().as_secs_f64());
    }
    Summary::of(&samples)
}

/// Render an aligned ASCII table (paper-style rows).
pub fn render_table(headers: &[&str], rows: &[Vec<String>]) -> String {
    let ncol = headers.len();
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate().take(ncol) {
            widths[i] = widths[i].max(cell.len());
        }
    }
    let mut out = String::new();
    let fmt_row = |cells: &[String], widths: &[usize]| -> String {
        let mut line = String::from("|");
        for (i, c) in cells.iter().enumerate() {
            line.push_str(&format!(" {:<w$} |", c, w = widths[i]));
        }
        line.push('\n');
        line
    };
    out.push_str(&fmt_row(
        &headers.iter().map(|s| s.to_string()).collect::<Vec<_>>(),
        &widths,
    ));
    let mut sep = String::from("|");
    for w in &widths {
        sep.push_str(&format!("{}|", "-".repeat(w + 2)));
    }
    sep.push('\n');
    out.push_str(&sep);
    for row in rows {
        out.push_str(&fmt_row(row, &widths));
    }
    out
}

/// Split a `…,"rows":[ {row},{row},… ]}` document into (prefix up to and
/// including the `[`, row-object strings). Row objects are flat — every
/// writer in this repo emits them with no nested braces and no braces
/// inside strings — so a depth counter over `{`/`}` is sufficient.
pub fn split_rows(doc: &str) -> Option<(&str, Vec<String>)> {
    let body = doc.strip_suffix("]}")?;
    let key = "\"rows\":[";
    let idx = body.rfind(key)?;
    let head_end = idx + key.len();
    let rows_text = &body[head_end..];
    let mut rows = Vec::new();
    let mut depth = 0usize;
    let mut start = 0usize;
    for (i, c) in rows_text.char_indices() {
        match c {
            '{' => {
                if depth == 0 {
                    start = i;
                }
                depth += 1;
            }
            '}' if depth > 0 => {
                depth -= 1;
                if depth == 0 {
                    rows.push(rows_text[start..=i].to_string());
                }
            }
            _ => {}
        }
    }
    Some((&doc[..head_end], rows))
}

/// Merge `rows` into the BENCH_micro.json at `path` (the micro bench
/// writes `rows` as the final key, so the document ends with `]}`).
/// Idempotent: any previous rows whose `"path"` value starts with
/// `row_prefix` are replaced, so running a bench standalone (or
/// repeatedly) never accumulates duplicates. Falls back to a standalone
/// `bench`-named document when the file is missing or not in the expected
/// shape.
pub fn merge_bench_rows(path: &str, bench: &str, row_prefix: &str, rows: &[String]) {
    let existing = std::fs::read_to_string(path).unwrap_or_default();
    let marker = format!("\"path\":\"{row_prefix}");
    let doc = match split_rows(existing.trim_end()) {
        Some((head, old_rows)) => {
            let mut all: Vec<String> =
                old_rows.into_iter().filter(|r| !r.contains(&marker)).collect();
            all.extend(rows.iter().cloned());
            format!("{head}{}]}}\n", all.join(","))
        }
        None => {
            crate::util::json::Obj::new()
                .str("bench", bench)
                .str("generated_by", "scripts/bench_micro.sh")
                .raw("rows", &format!("[{}]", rows.join(",")))
                .finish()
                + "\n"
        }
    };
    std::fs::write(path, doc).expect("write BENCH json");
    eprintln!("{bench}: merged {} rows into {path}", rows.len());
}

/// Format seconds compactly (µs/ms/s).
pub fn fmt_secs(s: f64) -> String {
    if s < 1e-3 {
        format!("{:.1}µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{:.2}s", s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_fn_counts_iters() {
        let mut calls = 0usize;
        let s = time_fn(2, 5, || calls += 1);
        assert_eq!(calls, 7);
        assert_eq!(s.n, 5);
        assert!(s.mean >= 0.0);
    }

    #[test]
    fn table_is_aligned() {
        let t = render_table(
            &["name", "value"],
            &[
                vec!["a".into(), "1".into()],
                vec!["longer-name".into(), "2".into()],
            ],
        );
        let lines: Vec<&str> = t.lines().collect();
        assert_eq!(lines.len(), 4);
        let w = lines[0].len();
        assert!(lines.iter().all(|l| l.len() == w), "{t}");
    }

    #[test]
    fn fmt_secs_ranges() {
        assert!(fmt_secs(5e-6).ends_with("µs"));
        assert!(fmt_secs(5e-3).ends_with("ms"));
        assert!(fmt_secs(2.0).ends_with('s'));
    }

    #[test]
    fn split_rows_roundtrips() {
        let doc = r#"{"bench":"micro","rows":[{"path":"a","mean_s":1},{"path":"b","mean_s":2}]}"#;
        let (head, rows) = split_rows(doc).unwrap();
        assert!(head.ends_with("\"rows\":["));
        assert_eq!(rows.len(), 2);
        assert!(rows[0].contains("\"path\":\"a\""));
        assert!(split_rows("not json").is_none());
        let empty = r#"{"bench":"micro","rows":[]}"#;
        let (_, rows) = split_rows(empty).unwrap();
        assert!(rows.is_empty());
    }

    /// merge_bench_rows must be idempotent: re-merging rows with the same
    /// prefix replaces, never accumulates; rows of other benches survive.
    #[test]
    fn merge_bench_rows_is_idempotent() {
        let dir = std::env::temp_dir().join("copris-test-bench-merge");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BENCH.json");
        let p = path.to_str().unwrap();
        std::fs::write(
            &path,
            "{\"bench\":\"micro\",\"rows\":[{\"path\":\"sampler\",\"mean_s\":1}]}\n",
        )
        .unwrap();
        let row = |name: &str, v: i64| format!("{{\"path\":\"kvb {name}\",\"mean_s\":{v}}}");
        merge_bench_rows(p, "kvb", "kvb ", &[row("x", 1), row("y", 2)]);
        merge_bench_rows(p, "kvb", "kvb ", &[row("x", 3)]);
        let text = std::fs::read_to_string(&path).unwrap();
        let (_, rows) = split_rows(text.trim_end()).unwrap();
        assert_eq!(rows.len(), 2, "{text}");
        assert!(rows.iter().any(|r| r.contains("\"path\":\"sampler\"")), "{text}");
        assert!(rows.iter().any(|r| r.contains("\"path\":\"kvb x\"") && r.contains(":3")), "{text}");
        assert!(!rows.iter().any(|r| r.contains("\"path\":\"kvb y\"")), "{text}");
        std::fs::remove_dir_all(&dir).ok();
    }
}
