//! Continuous-batching bench: slot admission vs token-budget packing with
//! chunked prefill, under a long-prompt long-tail mix (the pathology the
//! scheduler targets: long prompts arriving while co-resident sequences
//! are deep in their decode tails).
//!
//! Two cost views, both over bit-identical greedy token streams (pinned by
//! tests/continuous_batching.rs — only scheduling differs):
//!
//! **Simulated step-token utilization** (deterministic, counter-derived):
//! a fused engine step can compute up to BUDGET tokens (decode lanes +
//! prefill chunks in one launch). The chunked arm's unit count is its
//! actual step count — ingestion rides inside decode steps for free up to
//! the budget. The slot-admission arm pays its decode steps PLUS
//! ceil(prompt/BUDGET) dedicated prefill launches per admission (the
//! whole-prompt prefill is its own serial work). Utilization =
//! total tokens / (units × BUDGET). Chunked wins by absorbing prompt
//! ingestion into steps that were running anyway.
//!
//! **Measured step-time tail** (wall-clock, sleep-based): with a per-token
//! prefill delay, slot admission produces step-time SPIKES of
//! prompt_len × τ (every co-resident decode stalls behind the admission
//! prefill), while the packed schedule bounds per-step ingestion at
//! budget × τ — p95 step time is the paper's long-tail stall, tamed.
//!
//! Scale via COPRIS_BENCH_CB_ITEMS / COPRIS_BENCH_CB_BUDGET /
//! COPRIS_BENCH_DECODE_US / COPRIS_BENCH_PREFILL_US. With
//! COPRIS_BENCH_JSON set, rows merge idempotently into BENCH_micro.json.

use std::time::{Duration, Instant};

use copris::bench::{fmt_secs, merge_bench_rows, render_table};
use copris::engine::{
    Engine, EngineEvent, EngineOpts, KvCacheConfig, MockBackend, SamplingParams, WorkItem,
};
use copris::exp::common::env_usize;
use copris::util::json::Obj;

const MAX_SEQ: usize = 256;
const P_MAX: usize = 64;
const SLOTS: usize = 4;
const BLOCK: usize = 16;

/// The long-tail mix: every script is long (min_len below), and prompts
/// alternate short (decode-dominated) and long (ingestion-heavy, up to
/// p_max) — long arrivals land while earlier sequences are mid-tail.
fn workload(items: usize) -> Vec<(u64, Vec<i32>)> {
    (0..items as u64)
        .map(|i| {
            let plen = if i % 2 == 0 { 6 + (i as usize % 5) } else { P_MAX - (i as usize % 9) };
            let prompt: Vec<i32> =
                (0..plen).map(|t| 1 + ((t + i as usize) as i32 % 9)).collect();
            (i, prompt)
        })
        .collect()
}

fn item(id: u64, prompt: Vec<i32>) -> WorkItem {
    WorkItem {
        request_id: id,
        prompt: prompt.into(),
        resume: vec![],
        max_total: MAX_SEQ,
        sampling: SamplingParams::greedy(),
        retain: None,
        prefix: None,
    }
}

#[derive(Clone, Debug, Default)]
struct ArmResult {
    /// Engine steps driven (chunked: the only unit; legacy: decode units).
    steps: usize,
    /// Dedicated prefill launch units (legacy only): Σ ceil(plen/budget).
    prefill_units: usize,
    /// Prompt + generated tokens (identical across arms — streams are
    /// bit-identical).
    total_tokens: usize,
    /// total_tokens / ((steps + prefill_units) × budget).
    step_token_util: f64,
    /// Wall-clock for the run (sleep-based cost model).
    wall: f64,
    /// Mean / p95 measured duration of one `Engine::step` call.
    step_mean: f64,
    step_p95: f64,
    /// Engine-side stall-saved gauge (chunked arm; 0 for legacy).
    stall_saved: f64,
    completed: usize,
    prefill_chunks: u64,
}

fn run_arm(budget: usize, items: usize, decode_us: u64, prefill_us: u64) -> ArmResult {
    let mut be = MockBackend::new(SLOTS, MAX_SEQ);
    be.p_max = P_MAX;
    be.min_len = 40;
    be.spread = 8;
    if decode_us > 0 {
        be.decode_delay = Some(Duration::from_micros(decode_us));
    }
    if prefill_us > 0 {
        be.prefill_delay_per_token = Some(Duration::from_micros(prefill_us));
    }
    let kv = KvCacheConfig {
        block_size: BLOCK,
        budget_blocks: 0,
        prefix_sharing: true,
        ..KvCacheConfig::default()
    };
    let mut eng = Engine::with_opts(0, be, EngineOpts { kv, step_token_budget: budget }, 7);

    let work = workload(items);
    let mut r = ArmResult {
        total_tokens: work.iter().map(|(_, p)| p.len()).sum(),
        ..Default::default()
    };
    for (id, prompt) in &work {
        eng.submit(item(*id, prompt.clone())).unwrap();
    }
    let mut durs: Vec<f64> = Vec::new();
    let t0 = Instant::now();
    let mut ev = Vec::new();
    while eng.has_work() {
        let ts = Instant::now();
        eng.step(&mut ev).unwrap();
        durs.push(ts.elapsed().as_secs_f64());
        for e in ev.drain(..) {
            if let EngineEvent::Done { result, .. } = e {
                assert!(result.reason.is_complete(), "unbounded run must complete");
                r.completed += 1;
                r.total_tokens += result.new_tokens.len();
            }
        }
        r.steps += 1;
    }
    r.wall = t0.elapsed().as_secs_f64();
    r.prefill_chunks = eng.prefill_chunks;
    r.stall_saved = eng.prefill_stall_saved;
    durs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    r.step_mean = durs.iter().sum::<f64>() / durs.len().max(1) as f64;
    let p95_idx = (durs.len() * 95 / 100).min(durs.len().saturating_sub(1));
    r.step_p95 = durs.get(p95_idx).copied().unwrap_or(0.0);
    r
}

fn main() {
    let items = env_usize("COPRIS_BENCH_CB_ITEMS", 18);
    let budget = env_usize("COPRIS_BENCH_CB_BUDGET", 16);
    let decode_us = env_usize("COPRIS_BENCH_DECODE_US", 200) as u64;
    let prefill_us = env_usize("COPRIS_BENCH_PREFILL_US", 40) as u64;

    println!(
        "== continuous_batching: slot admission vs token-budget packing (mock backend) ==\n\
         {items} items (short/long prompt mix, long decode tails), {SLOTS} slots, \
         budget {budget} tok/step, p_max {P_MAX}, decode {decode_us}us/step, \
         prefill {prefill_us}us/token\n"
    );

    let mut legacy = run_arm(0, items, decode_us, prefill_us);
    let mut chunked = run_arm(budget, items, decode_us, prefill_us);
    assert_eq!(legacy.completed, chunked.completed, "arms must do identical work");
    assert_eq!(
        legacy.total_tokens, chunked.total_tokens,
        "bit-identical streams imply identical token totals"
    );

    // Legacy pays a dedicated launch unit per ceil(plen/budget) of every
    // admission (its prefill is serial whole-prompt work); the chunked
    // arm's ingestion already rode inside its counted steps.
    legacy.prefill_units =
        workload(items).iter().map(|(_, p)| p.len().div_ceil(budget)).sum();
    let util = |r: &ArmResult| {
        r.total_tokens as f64 / (((r.steps + r.prefill_units) * budget) as f64)
    };
    legacy.step_token_util = util(&legacy);
    chunked.step_token_util = util(&chunked);

    let headers = [
        "Arm", "Units (steps+prefill)", "Tokens", "Step-token util", "Wall",
        "Step mean", "Step p95", "Chunks", "Stall saved",
    ];
    let rows: Vec<Vec<String>> = [("slot-admission", &legacy), ("chunked-cb", &chunked)]
        .iter()
        .map(|(name, r)| {
            vec![
                name.to_string(),
                format!("{} (+{})", r.steps, r.prefill_units),
                r.total_tokens.to_string(),
                format!("{:.3}", r.step_token_util),
                fmt_secs(r.wall),
                fmt_secs(r.step_mean),
                fmt_secs(r.step_p95),
                r.prefill_chunks.to_string(),
                fmt_secs(r.stall_saved),
            ]
        })
        .collect();
    println!("{}", render_table(&headers, &rows));
    println!(
        "\nexpected shape: identical Tokens (streams are bit-identical); `chunked-cb`\n\
         absorbs prompt ingestion into running decode steps, so its unit count is\n\
         lower and its simulated step-token utilization HIGHER than `slot-admission`\n\
         (which pays dedicated prefill launches); its measured step p95 is also\n\
         bounded near budget×prefill-delay instead of spiking at p_max×delay.\n\
         util: chunked {:.3} vs slot {:.3}  ({:+.1}%)",
        chunked.step_token_util,
        legacy.step_token_util,
        (chunked.step_token_util / legacy.step_token_util.max(1e-12) - 1.0) * 100.0,
    );
    assert!(
        chunked.step_token_util > legacy.step_token_util,
        "chunked continuous batching must beat slot admission on simulated \
         step-token utilization ({:.3} vs {:.3})",
        chunked.step_token_util,
        legacy.step_token_util
    );

    if let Ok(path) = std::env::var("COPRIS_BENCH_JSON") {
        let entries: Vec<String> = [("slot-admission", &legacy), ("chunked-cb", &chunked)]
            .iter()
            .map(|(name, r)| {
                Obj::new()
                    .str("path", &format!("continuous_batching {name}"))
                    .num("mean_s", r.step_mean)
                    .num("p50_s", r.step_mean)
                    .num("p95_s", r.step_p95)
                    .int("iters", r.steps as i64)
                    .num("step_token_util", r.step_token_util)
                    .int("units", (r.steps + r.prefill_units) as i64)
                    .int("total_tokens", r.total_tokens as i64)
                    .int("prefill_chunks", r.prefill_chunks as i64)
                    .num("wall_s", r.wall)
                    .finish()
            })
            .collect();
        merge_bench_rows(&path, "continuous_batching", "continuous_batching", &entries);
    }
}
