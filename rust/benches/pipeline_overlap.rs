//! Serial vs stage-pipelined CoPRIS, end-to-end wall clock at equal batch
//! count on the mock backend (table1-style arm for the pipelining PR).
//! The mock's per-step decode delay stands in for GPU decode time; the
//! simulated trainer window stands in for cal-logprob → grad → update.
//! Scale via COPRIS_BENCH_STEPS / COPRIS_BENCH_TRAIN_MS /
//! COPRIS_BENCH_DECODE_US.

use std::time::Duration;

use copris::bench::render_table;
use copris::exp::common::env_usize;
use copris::exp::pipesim::{run, PipeSimOpts};

fn main() {
    let mut opts = PipeSimOpts::default();
    opts.steps = env_usize("COPRIS_BENCH_STEPS", 8);
    opts.train_secs = env_usize("COPRIS_BENCH_TRAIN_MS", 60) as f64 / 1e3;
    opts.decode_delay =
        Duration::from_micros(env_usize("COPRIS_BENCH_DECODE_US", 1000) as u64);

    println!(
        "== pipeline_overlap: serial vs stage-pipelined CoPRIS (mock backend) ==\n\
         {} steps, B={} G={} N'={}, decode {:?}/step, simulated train {:.0}ms/step\n",
        opts.steps,
        opts.cfg.rollout.batch_prompts,
        opts.cfg.rollout.group_size,
        opts.cfg.rollout.concurrency,
        opts.decode_delay,
        opts.train_secs * 1e3,
    );

    let (serial, _) = run(&opts, false).expect("serial arm");
    let (piped, _) = run(&opts, true).expect("pipelined arm");

    let headers = [
        "Arm", "Wall s", "Groups", "Samples", "Rollout s", "Overlap s",
        "Lagged trajs", "Resumed", "Speedup",
    ];
    let row = |name: &str, s: &copris::exp::pipesim::PipeSimSummary, speedup: f64| {
        vec![
            name.to_string(),
            format!("{:.2}", s.wall),
            s.groups.to_string(),
            s.samples.to_string(),
            format!("{:.2}", s.rollout_secs),
            format!("{:.2}", s.overlap_secs),
            s.lagged_trajectories.to_string(),
            s.resumed.to_string(),
            if speedup > 0.0 { format!("{speedup:.2}x") } else { "-".into() },
        ]
    };
    let rows = vec![
        row("serial copris", &serial, 0.0),
        row("pipelined copris", &piped, serial.wall / piped.wall.max(1e-9)),
    ];
    println!("{}", render_table(&headers, &rows));
    println!(
        "\nexpected shape: pipelined wall ≈ max(rollout, train) per step instead of\n\
         rollout + train; mid-flight syncs surface as lagged (multi-segment) trajectories."
    );
}
