//! Regenerates paper Fig. 3: CoPRIS vs sync throughput + speedup across
//! context lengths (requires `make artifacts-fig3`) and model sizes.

use copris::exp::common::env_usize;
use copris::exp::fig3;

fn main() {
    let sft = env_usize("COPRIS_BENCH_SFT", 60);
    let steps = env_usize("COPRIS_BENCH_STEPS", 8);
    let (ctx, sizes) = fig3::run(sft, steps).expect("fig3 run");
    println!("{}", fig3::render(&ctx, &sizes));
}
