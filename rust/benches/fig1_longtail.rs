//! Regenerates paper Fig. 1: the long-tail problem — response-length
//! distribution + per-engine utilization dips under synchronous rollout.
//! Scale via COPRIS_BENCH_MODEL / COPRIS_BENCH_SFT.

use copris::exp::common::{artifacts_available, env_str, env_usize};
use copris::exp::fig1;

fn main() {
    let model = env_str("COPRIS_BENCH_MODEL", "small");
    let sft = env_usize("COPRIS_BENCH_SFT", 60);
    if !artifacts_available(&model) {
        eprintln!("fig1: artifacts/{model} missing — run `make artifacts`");
        return;
    }
    let report = fig1::run(&model, sft).expect("fig1 run");
    println!("{}", fig1::render(&report));
}
