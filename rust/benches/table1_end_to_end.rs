//! Regenerates paper Table 1: Basemodel vs veRL (sync) vs CoPRIS across
//! model scales — pass@1 on the five suites, RL wall-clock, speedup.
//! Scale via COPRIS_BENCH_MODELS (comma list) / COPRIS_BENCH_STEPS /
//! COPRIS_BENCH_SFT.

use copris::exp::common::{artifacts_available, env_str, env_usize};
use copris::exp::table1;

fn main() {
    let models_env = env_str("COPRIS_BENCH_MODELS", "small");
    let models: Vec<&str> =
        models_env.split(',').filter(|m| artifacts_available(m)).collect();
    if models.is_empty() {
        eprintln!("table1: no artifacts found — run `make artifacts`");
        return;
    }
    let sft = env_usize("COPRIS_BENCH_SFT", 80);
    let steps = env_usize("COPRIS_BENCH_STEPS", 16);
    let rows = table1::run(&models, sft, steps).expect("table1 run");
    println!("{}", table1::render(&rows));
}
