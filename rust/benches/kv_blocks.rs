//! Paged KV-cache bench: flat-token budget baseline vs blocks-denominated
//! budget with copy-on-write prompt-prefix sharing across GRPO groups —
//! measures the residency economy the `engine/kvcache` subsystem buys
//! under a bounded KV budget: admitted concurrency (busy slots), peak
//! block residency, stage wall, preemptions, and the sharing/COW counters.
//!
//! Arms (greedy sampling — token streams are bit-identical across arms,
//! pinned by tests/retained_golden.rs; only scheduling/residency differ):
//!
//!   flat-token            budget stated in tokens and converted via
//!                         KvCacheConfig::from_token_budget (blocks =
//!                         ceil(tokens/block)), sharing off — the
//!                         pre-subsystem baseline. (The config-level
//!                         engine.kv_budget_tokens knob was removed.)
//!   paged-private         same budget stated in blocks, sharing off —
//!                         must behave identically to flat-token (the
//!                         conversion sanity row).
//!   paged-shared          same budget, prefix sharing on: each group's G
//!                         samples hold ONE refcounted copy of the prompt
//!                         blocks, so more rollouts fit the budget —
//!                         higher admitted concurrency, fewer
//!                         backpressure/preemption stalls, lower wall.
//!
//! Scale via COPRIS_BENCH_STAGES / COPRIS_BENCH_DECODE_US /
//! COPRIS_BENCH_KV_BLOCKS. With COPRIS_BENCH_JSON set, rows are merged
//! idempotently into BENCH_micro.json (scripts/bench_micro.sh runs micro
//! first, then this and resume_affinity).

use std::time::{Duration, Instant};

use copris::bench::{fmt_secs, merge_bench_rows, render_table};
use copris::config::{Config, RolloutMode};
use copris::coordinator::Coordinator;
use copris::engine::{EnginePool, MockBackend};
use copris::exp::common::env_usize;
use copris::tasks::Dataset;
use copris::util::json::Obj;

const MAX_SEQ: usize = 96;
const BLOCK_SIZE: usize = 8;

#[derive(Clone, Debug, Default)]
struct ArmResult {
    stage_secs: f64,
    completed: usize,
    peak_active: usize,
    mean_util: f64,
    kv_blocks_peak: usize,
    prefix_tokens_shared: u64,
    cow_copies: u64,
    preemptions: u64,
    kv_frag: f64,
}

struct ArmOpts {
    /// Budget in blocks; stated in tokens and converted when
    /// `legacy_tokens` is set (exercises the conversion path).
    budget_blocks: usize,
    legacy_tokens: bool,
    sharing: bool,
    stages: usize,
    decode_us: u64,
}

fn run_arm(o: &ArmOpts) -> ArmResult {
    let mut cfg = Config::new("mock");
    cfg.rollout.mode = RolloutMode::Copris;
    cfg.rollout.batch_prompts = 3;
    cfg.rollout.group_size = 4; // G=4: the prefix-sharing material
    cfg.rollout.concurrency = 16;
    cfg.rollout.temperature = 0.0; // greedy: identical streams across arms
    cfg.engine.engines = 1; // sharing needs siblings co-located anyway
    cfg.engine.kv_block_size = BLOCK_SIZE;
    cfg.engine.prefix_sharing = o.sharing;
    if o.legacy_tokens {
        // Token-denominated statement of the same budget, converted via
        // KvCacheConfig::from_token_budget — the config-level
        // kv_budget_tokens knob was removed, so the conversion sanity row
        // states the tokens here and converts explicitly.
        cfg.engine.kv_budget_blocks =
            copris::engine::KvCacheConfig::from_token_budget(o.budget_blocks * BLOCK_SIZE, BLOCK_SIZE)
                .budget_blocks;
    } else {
        cfg.engine.kv_budget_blocks = o.budget_blocks;
    }
    cfg.train.seed = 11;
    let slots = 8;
    let decode = Duration::from_micros(o.decode_us);
    let pool = EnginePool::spawn_kv(
        cfg.engine.engines,
        slots,
        cfg.engine.kv_cache_config(),
        cfg.train.seed,
        move |_id| {
            Box::new(move || {
                let mut b = MockBackend::new(slots, MAX_SEQ);
                // Long scripts: chains span several blocks, so the budget
                // actually binds.
                b.min_len = 24;
                b.spread = 16;
                b.decode_delay = Some(decode);
                Ok(b)
            })
        },
    )
    .expect("spawn pool");
    let mut coord = Coordinator::new(pool, cfg.clone(), MAX_SEQ);
    let mut ds = Dataset::train(cfg.train.seed);

    let mut r = ArmResult::default();
    let mut util_sum = 0.0f64;
    let mut util_n = 0usize;
    let mut frag_sum = 0.0f64;
    let mut frag_n = 0usize;
    for _ in 0..o.stages {
        let out = coord.rollout_stage(&mut ds).expect("stage");
        r.stage_secs += out.stats.wall;
        r.completed += out.stats.completed;
        r.kv_blocks_peak = r.kv_blocks_peak.max(out.stats.kv_blocks_peak);
        r.prefix_tokens_shared += out.stats.prefix_tokens_shared;
        r.cow_copies += out.stats.cow_copies;
        r.preemptions += out.stats.preemptions;
        for t in &out.stats.traces {
            r.peak_active = r.peak_active.max(t.active);
            util_sum += t.active as f64 / t.slots as f64;
            util_n += 1;
            if t.kv_blocks > 0 {
                frag_sum += t.kv_frag;
                frag_n += 1;
            }
        }
    }
    r.mean_util = if util_n == 0 { 0.0 } else { util_sum / util_n as f64 };
    r.kv_frag = if frag_n == 0 { 0.0 } else { frag_sum / frag_n as f64 };
    coord.shutdown();
    r
}

fn main() {
    let stages = env_usize("COPRIS_BENCH_STAGES", 6);
    let decode_us = env_usize("COPRIS_BENCH_DECODE_US", 800) as u64;
    let budget_blocks = env_usize("COPRIS_BENCH_KV_BLOCKS", 24);

    println!(
        "== kv_blocks: flat-token baseline vs paged KV with prefix sharing (mock backend) ==\n\
         {stages} stages, B=3 G=4 N'=16, 1 engine x 8 slots, block {BLOCK_SIZE} tok, \
         budget {budget_blocks} blocks, decode {decode_us}us/step\n"
    );

    let arms: Vec<(&str, ArmOpts)> = vec![
        (
            "flat-token",
            ArmOpts {
                budget_blocks,
                legacy_tokens: true,
                sharing: false,
                stages,
                decode_us,
            },
        ),
        (
            "paged-private",
            ArmOpts {
                budget_blocks,
                legacy_tokens: false,
                sharing: false,
                stages,
                decode_us,
            },
        ),
        (
            "paged-shared",
            ArmOpts {
                budget_blocks,
                legacy_tokens: false,
                sharing: true,
                stages,
                decode_us,
            },
        ),
    ];

    let mut results: Vec<(&str, ArmResult)> = Vec::new();
    for (name, opts) in &arms {
        results.push((*name, run_arm(opts)));
    }

    let baseline = results[0].1.stage_secs;
    let headers = [
        "Arm", "Stage s (sum)", "Speedup", "Completed", "Peak busy", "Mean util",
        "Peak blocks", "Shared tok", "COW", "Preempt", "Frag",
    ];
    let rows: Vec<Vec<String>> = results
        .iter()
        .map(|(name, r)| {
            vec![
                name.to_string(),
                format!("{:.3}", r.stage_secs),
                format!("{:.2}x", baseline / r.stage_secs.max(1e-9)),
                r.completed.to_string(),
                r.peak_active.to_string(),
                format!("{:.0}%", r.mean_util * 100.0),
                r.kv_blocks_peak.to_string(),
                r.prefix_tokens_shared.to_string(),
                r.cow_copies.to_string(),
                r.preemptions.to_string(),
                format!("{:.2}", r.kv_frag),
            ]
        })
        .collect();
    println!("{}", render_table(&headers, &rows));
    println!(
        "\nexpected shape: `paged-private` == `flat-token` (the ceil conversion is exact\n\
         at block multiples); `paged-shared` shows shared tok > 0 with one COW per\n\
         diverging sample, a LOWER peak-block footprint for the same work, admitted\n\
         concurrency >= the private arms, and stage wall <= baseline.\n\
         mean stage wall (shared arm): {}",
        fmt_secs(results[2].1.stage_secs / stages.max(1) as f64),
    );

    // Machine-readable rows merged into BENCH_micro.json.
    if let Ok(path) = std::env::var("COPRIS_BENCH_JSON") {
        let entries: Vec<String> = results
            .iter()
            .map(|(name, r)| {
                Obj::new()
                    .str("path", &format!("kv_blocks {name} (stage wall)"))
                    .num("mean_s", r.stage_secs / stages.max(1) as f64)
                    .num("p50_s", r.stage_secs / stages.max(1) as f64)
                    .num("p95_s", r.stage_secs / stages.max(1) as f64)
                    .int("iters", stages as i64)
                    .int("peak_busy", r.peak_active as i64)
                    .int("kv_blocks_peak", r.kv_blocks_peak as i64)
                    .int("prefix_tokens_shared", r.prefix_tokens_shared as i64)
                    .int("cow_copies", r.cow_copies as i64)
                    .int("preemptions", r.preemptions as i64)
                    .finish()
            })
            .collect();
        merge_bench_rows(&path, "kv_blocks", "kv_blocks", &entries);
    }
}
