//! Microbenchmarks of the hot paths (hand-rolled harness; criterion is not
//! in the vendored crate set): sampler, buffer ops, mock decode, and the
//! artifact-level prefill/decode/logprob/grad/update ops.

use copris::bench::{fmt_secs, render_table, time_fn};
use copris::coordinator::PartialBuffer;
use copris::coordinator::Trajectory;
use copris::engine::{sample_token, Backend, MockBackend, SamplingParams};
use copris::exp::common::{artifacts_available, env_str};
use copris::model::ModelRuntime;
use copris::tasks::Family;
use copris::util::Rng;

fn main() {
    let mut rows: Vec<Vec<String>> = Vec::new();

    // -- L3 pure-coordination paths ------------------------------------
    let mut rng = Rng::new(1);
    let logits: Vec<f32> = (0..48).map(|i| ((i * 7) % 13) as f32 * 0.3).collect();
    let s = time_fn(100, 2000, || {
        sample_token(&logits, &SamplingParams::default(), &mut rng)
    });
    rows.push(vec!["sampler (48-vocab)".into(), fmt_secs(s.mean), fmt_secs(s.p95)]);

    let task = Family::Countdown.generate(&mut Rng::new(2), 2);
    let mut buf = PartialBuffer::new(usize::MAX);
    let mut id = 0u64;
    let s = time_fn(100, 2000, || {
        id += 1;
        let mut t = Trajectory::new(id, id, task.clone(), vec![1, 5, 6], id % 7);
        t.append_stage(&[5; 24], &[-0.5; 24], id % 7);
        buf.push(t);
        if id % 2 == 0 {
            buf.pop();
        }
    });
    rows.push(vec!["buffer push/pop (24-tok)".into(), fmt_secs(s.mean), fmt_secs(s.p95)]);

    let mut mock = MockBackend::new(8, 192);
    mock.prefill(0, &[1, 5, 6]).unwrap();
    let toks = vec![5i32; 8];
    let pos = vec![3i32; 8];
    let s = time_fn(100, 2000, || mock.decode(&toks, &pos).unwrap());
    rows.push(vec!["mock decode step (8 slots)".into(), fmt_secs(s.mean), fmt_secs(s.p95)]);

    // -- artifact-level (needs artifacts) --------------------------------
    let model = env_str("COPRIS_BENCH_MODEL", "small");
    if artifacts_available(&model) {
        let mut rt = ModelRuntime::open("artifacts", &model).expect("open runtime");
        let spec = rt.spec.clone();
        let state = rt.init_state(1).unwrap();
        let params_host = rt.params_to_host(&state).unwrap();
        let params = rt.upload_params(&params_host).unwrap();
        let mut es = rt.fresh_engine_state().unwrap();
        let toks = vec![5i32; spec.slots];
        let pos: Vec<i32> = (0..spec.slots as i32).map(|i| 10 + i).collect();

        let s = time_fn(3, 30, || {
            let (es2, _) = rt.decode(&params, &es, &toks, &pos).unwrap();
            es = es2;
        });
        rows.push(vec![
            format!("xla decode step ({} slots, {})", spec.slots, model),
            fmt_secs(s.mean),
            fmt_secs(s.p95),
        ]);

        let prompt: Vec<i32> = (0..16).map(|i| 4 + i % 10).collect();
        let s = time_fn(2, 20, || {
            let (es2, _) = rt.prefill(&params, &es, &prompt, 0).unwrap();
            es = es2;
        });
        rows.push(vec![
            format!("xla prefill 16-tok ({model})"),
            fmt_secs(s.mean),
            fmt_secs(s.p95),
        ]);

        let (b, t) = (spec.b_micro, spec.t_train);
        let tokens: Vec<i32> = (0..b * t).map(|i| 4 + (i % 10) as i32).collect();
        let s = time_fn(2, 10, || rt.logprob(&state, &tokens).unwrap());
        rows.push(vec![
            format!("xla logprob [{b},{t}]"),
            fmt_secs(s.mean),
            fmt_secs(s.p95),
        ]);

        let mask = vec![1f32; b * (t - 1)];
        let behav = vec![-1f32; b * (t - 1)];
        let adv = vec![0.5f32; b];
        let s = time_fn(2, 10, || rt.grad(&state, &tokens, &mask, &behav, &adv).unwrap());
        rows.push(vec![
            format!("xla grad [{b},{t}]"),
            fmt_secs(s.mean),
            fmt_secs(s.p95),
        ]);

        let (g, _) = rt.grad(&state, &tokens, &mask, &behav, &adv).unwrap();
        let s = time_fn(2, 20, || rt.update(&state, &g, 1, 1e-4, 1.0).unwrap());
        rows.push(vec![
            format!("xla adam update ({} params)", spec.n_params),
            fmt_secs(s.mean),
            fmt_secs(s.p95),
        ]);

        let s = time_fn(2, 20, || rt.params_to_host(&state).unwrap());
        rows.push(vec!["weight-sync host read".into(), fmt_secs(s.mean), fmt_secs(s.p95)]);
    } else {
        eprintln!("micro: artifacts/{model} missing — artifact rows skipped");
    }

    println!("== microbenchmarks ==");
    println!("{}", render_table(&["path", "mean", "p95"], &rows));
}
