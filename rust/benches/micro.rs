//! Microbenchmarks of the hot paths (hand-rolled harness; criterion is not
//! in the vendored crate set): sampler, buffer ops, mock decode, engine
//! step, event delivery, and the artifact-level prefill/decode/logprob/
//! grad/update ops.
//!
//! Layers touched by the zero-allocation decode-path PR carry explicit
//! before/after row pairs: the "seed-path" rows reproduce the pre-rewrite
//! cost model in-binary (allocating sampler via `sampler::reference`, a
//! local replica of the old per-row-allocating mock decode, per-event
//! channel sends, prompt deep-copy dispatch) so the speedup is measured
//! on the same machine in the same run — `scripts/bench_micro.sh` records
//! the table to `BENCH_micro.json` and `EXPERIMENTS.md §Perf` tracks it.

use copris::bench::{fmt_secs, render_table, time_fn};
use copris::coordinator::PartialBuffer;
use copris::coordinator::Trajectory;
use copris::engine::sampler::reference::sample_token_ref;
use copris::engine::{
    sample_token_dispatched, sample_token_with, Backend, Engine, EngineEvent, MockBackend,
    SamplerDispatch, SamplerScratch, SamplingParams, StepTrace, WorkItem,
};
use copris::exp::common::{artifacts_available, env_str};
use copris::model::ModelRuntime;
use copris::tasks::Family;
use copris::util::json::Obj;
use copris::util::stats::Summary;
use copris::util::Rng;

/// In-binary replica of the seed `MockBackend::decode`: fresh S×V output
/// vec + one freshly allocated row per slot per step. Kept here (not in the
/// library) purely as the "before" cost model.
fn seed_mock_decode(
    script: &mut [(u64, usize)],
    vocab: usize,
    min_len: usize,
    spread: usize,
) -> Vec<f32> {
    let slots = script.len();
    let mut out = Vec::with_capacity(slots * vocab);
    for s in 0..slots {
        let (h, count) = script[s];
        let scripted = min_len + (h % spread as u64) as usize;
        let step = count + 1;
        let mut row = vec![-20.0f32; vocab];
        if step >= scripted {
            row[2] = 10.0; // EOS
        } else {
            let tok = 4 + ((h >> (step % 48)) % 10) as usize;
            row[tok] = 10.0;
            row[(tok + 1) % 14] = 6.0;
        }
        out.extend(row);
        script[s].1 = count + 1;
    }
    out
}

fn main() {
    let mut rows: Vec<(String, Summary)> = Vec::new();
    fn push(rows: &mut Vec<(String, Summary)>, name: &str, s: Summary) {
        rows.push((name.to_string(), s));
    }

    // -- L3 pure-coordination paths ------------------------------------
    let logits: Vec<f32> = (0..48).map(|i| ((i * 7) % 13) as f32 * 0.3).collect();

    let mut rng = Rng::new(1);
    let s = time_fn(100, 2000, || {
        sample_token_ref(&logits, &SamplingParams::default(), &mut rng)
    });
    push(&mut rows, "sampler seed-path (48-vocab, default)", s);

    let mut rng = Rng::new(1);
    let mut scratch = SamplerScratch::new();
    let s = time_fn(100, 2000, || {
        sample_token_with(&logits, &SamplingParams::default(), &mut rng, &mut scratch)
    });
    push(&mut rows, "sampler scratch (48-vocab, default)", s);

    let filtered = SamplingParams { temperature: 1.0, top_p: 0.9, top_k: 8 };
    let mut rng = Rng::new(1);
    let s = time_fn(100, 2000, || sample_token_ref(&logits, &filtered, &mut rng));
    push(&mut rows, "sampler seed-path (48-vocab, top-k8 top-p0.9)", s);

    let mut rng = Rng::new(1);
    let s = time_fn(100, 2000, || {
        sample_token_with(&logits, &filtered, &mut rng, &mut scratch)
    });
    push(&mut rows, "sampler scratch (48-vocab, top-k8 top-p0.9)", s);

    // Runtime-dispatched SIMD arms over the same workloads (scalar rows
    // above are the "before"; each available arm is bit-identical to them
    // by the fuzz oracle, so only the time differs).
    for d in SamplerDispatch::available() {
        let mut rng = Rng::new(1);
        let s = time_fn(100, 2000, || {
            sample_token_dispatched(&logits, &SamplingParams::default(), &mut rng, &mut scratch, d)
        });
        push(&mut rows, &format!("sampler {} (48-vocab, default)", d.name()), s);
        let mut rng = Rng::new(1);
        let s = time_fn(100, 2000, || {
            sample_token_dispatched(&logits, &filtered, &mut rng, &mut scratch, d)
        });
        push(&mut rows, &format!("sampler {} (48-vocab, top-k8 top-p0.9)", d.name()), s);
    }

    let task = Family::Countdown.generate(&mut Rng::new(2), 2);
    let mut buf = PartialBuffer::new(usize::MAX);
    let mut id = 0u64;
    let s = time_fn(100, 2000, || {
        id += 1;
        let mut t = Trajectory::new(id, id, task.clone(), vec![1, 5, 6], id % 7);
        t.append_stage(&[5; 24], &[-0.5; 24], id % 7);
        buf.push(t);
        if id % 2 == 0 {
            buf.pop();
        }
    });
    push(&mut rows, "buffer push/pop (24-tok)", s);

    // Prompt hand-off at dispatch: deep copy (seed) vs Arc clone.
    let prompt_vec: Vec<i32> = (0..256).map(|i| 4 + i % 10).collect();
    let s = time_fn(100, 2000, || std::hint::black_box(prompt_vec.clone()));
    push(&mut rows, "dispatch prompt deep-copy (256-tok, seed-path)", s);
    let prompt_arc: std::sync::Arc<[i32]> = prompt_vec.clone().into();
    let s = time_fn(100, 2000, || std::hint::black_box(prompt_arc.clone()));
    push(&mut rows, "dispatch prompt arc-clone (256-tok)", s);

    // Mock decode step: seed replica (row alloc per slot) vs decode_into.
    let mut mock = MockBackend::new(8, 192);
    mock.prefill(0, &[1, 5, 6]).unwrap();
    let mut seed_script = vec![(0x9e3779b97f4a7c15u64, 0usize); 8];
    let s = time_fn(100, 2000, || {
        std::hint::black_box(seed_mock_decode(&mut seed_script, 48, 2, 12))
    });
    push(&mut rows, "mock decode step seed-path (8 slots)", s);

    let toks = vec![5i32; 8];
    let pos = vec![3i32; 8];
    let mut logits_buf = Vec::new();
    let s = time_fn(100, 2000, || mock.decode_into(&toks, &pos, &mut logits_buf).unwrap());
    push(&mut rows, "mock decode step into (8 slots)", s);

    // Full engine scheduler iteration at steady state (4 busy slots):
    // admit check + decode_into + 4 sampler calls + trace, no allocation.
    let mut be = MockBackend::new(4, 8192);
    be.min_len = 5000; // never finishes inside the bench window
    be.spread = 1;
    let mut eng = Engine::new(0, be, 0, 1);
    for i in 0..4u64 {
        eng.submit(WorkItem {
            request_id: i,
            prompt: vec![1, i as i32 + 4, 9].into(),
            resume: vec![],
            max_total: 8192,
            sampling: SamplingParams::default(),
            retain: None,
            prefix: None,
        })
        .unwrap();
    }
    let mut ev: Vec<EngineEvent> = Vec::with_capacity(16);
    let s = time_fn(100, 2000, || {
        eng.step(&mut ev).unwrap();
        ev.clear();
    });
    push(&mut rows, "engine steady decode step (4 slots, mock)", s);

    // Event delivery: one mpsc send per event (seed) vs one Batch send.
    let trace = StepTrace {
        engine: 0,
        t_wall: 0.0,
        dur: 0.0,
        active: 4,
        slots: 4,
        kv_tokens: 128,
        kv_blocks: 8,
        kv_frag: 0.0,
        prefix_tokens_shared: 0,
        cow_copies: 0,
        preemptions: 0,
        step_tokens: 4,
        step_budget: 0,
        prefill_chunks: 0,
        prefill_stall_saved: 0.0,
        retries: 0,
        kv_bytes: 8 * 16 * 256 * 4,
        sampler_dispatch: "scalar",
        queued: 0,
    };
    let (tx, rx) = std::sync::mpsc::channel::<EngineEvent>();
    let s = time_fn(100, 2000, || {
        for _ in 0..3 {
            tx.send(EngineEvent::Trace(trace.clone())).unwrap();
        }
        while rx.try_recv().is_ok() {}
    });
    push(&mut rows, "event flush per-event (3 events, seed-path)", s);
    let s = time_fn(100, 2000, || {
        let batch = vec![
            EngineEvent::Trace(trace.clone()),
            EngineEvent::Trace(trace.clone()),
            EngineEvent::Trace(trace.clone()),
        ];
        tx.send(EngineEvent::Batch(batch)).unwrap();
        while rx.try_recv().is_ok() {}
    });
    push(&mut rows, "event flush batched (3 events)", s);

    // -- artifact-level (needs artifacts) --------------------------------
    let model = env_str("COPRIS_BENCH_MODEL", "small");
    if artifacts_available(&model) {
        let mut rt = ModelRuntime::open("artifacts", &model).expect("open runtime");
        let spec = rt.spec.clone();
        let state = rt.init_state(1).unwrap();
        let params_host = rt.params_to_host(&state).unwrap();
        let params = rt.upload_params(&params_host).unwrap();
        let mut es = rt.fresh_engine_state().unwrap();
        let toks = vec![5i32; spec.slots];
        let pos: Vec<i32> = (0..spec.slots as i32).map(|i| 10 + i).collect();

        let s = time_fn(3, 30, || {
            let (es2, _) = rt.decode(&params, &es, &toks, &pos).unwrap();
            es = es2;
        });
        push(&mut rows, &format!("xla decode step ({} slots, {})", spec.slots, model), s);

        let mut dev_logits = Vec::new();
        let s = time_fn(3, 30, || {
            let es2 = rt.decode_into(&params, &es, &toks, &pos, &mut dev_logits).unwrap();
            es = es2;
        });
        push(&mut rows, &format!("xla decode step into ({} slots, {})", spec.slots, model), s);

        let prompt: Vec<i32> = (0..16).map(|i| 4 + i % 10).collect();
        let s = time_fn(2, 20, || {
            let (es2, _) = rt.prefill(&params, &es, &prompt, 0).unwrap();
            es = es2;
        });
        push(&mut rows, &format!("xla prefill 16-tok ({model})"), s);

        let (b, t) = (spec.b_micro, spec.t_train);
        let tokens: Vec<i32> = (0..b * t).map(|i| 4 + (i % 10) as i32).collect();
        let s = time_fn(2, 10, || rt.logprob(&state, &tokens).unwrap());
        push(&mut rows, &format!("xla logprob [{b},{t}]"), s);

        let mask = vec![1f32; b * (t - 1)];
        let behav = vec![-1f32; b * (t - 1)];
        let adv = vec![0.5f32; b];
        let s = time_fn(2, 10, || rt.grad(&state, &tokens, &mask, &behav, &adv).unwrap());
        push(&mut rows, &format!("xla grad [{b},{t}]"), s);

        let (g, _) = rt.grad(&state, &tokens, &mask, &behav, &adv).unwrap();
        let s = time_fn(2, 20, || rt.update(&state, &g, 1, 1e-4, 1.0).unwrap());
        push(&mut rows, &format!("xla adam update ({} params)", spec.n_params), s);

        let s = time_fn(2, 20, || rt.params_to_host(&state).unwrap());
        push(&mut rows, "weight-sync host read", s);
    } else {
        eprintln!("micro: artifacts/{model} missing — artifact rows skipped");
    }

    let table_rows: Vec<Vec<String>> = rows
        .iter()
        .map(|(name, s)| vec![name.clone(), fmt_secs(s.mean), fmt_secs(s.p95)])
        .collect();
    println!("== microbenchmarks ==");
    println!("{}", render_table(&["path", "mean", "p95"], &table_rows));

    // Machine-readable output for scripts/bench_micro.sh → BENCH_micro.json.
    if let Ok(path) = std::env::var("COPRIS_BENCH_JSON") {
        let entries: Vec<String> = rows
            .iter()
            .map(|(name, s)| {
                Obj::new()
                    .str("path", name)
                    .num("mean_s", s.mean)
                    .num("p50_s", s.p50)
                    .num("p95_s", s.p95)
                    .int("iters", s.n as i64)
                    .finish()
            })
            .collect();
        let doc = Obj::new()
            .str("bench", "micro")
            .str("generated_by", "scripts/bench_micro.sh")
            .raw("rows", &format!("[{}]", entries.join(",")))
            .finish();
        std::fs::write(&path, doc + "\n").expect("write BENCH json");
        eprintln!("micro: wrote {path}");
    }
}
