//! SIMD-sampler + quantized-KV bench: the two halves of the perf PR.
//!
//! **Sampler throughput** — one row per runtime-dispatch arm actually
//! available on this host (scalar always; avx2/avx512 when detected) over
//! the two hot-path workloads (default sampling and top-k+top-p
//! filtering) at a small and a large vocab. Every arm produces
//! bit-identical token/log-prob/RNG streams (pinned by the differential
//! fuzz in `engine::sampler`), so the rows differ in time only; the bench
//! re-asserts stream equality in-binary before timing so a row can never
//! describe a divergent arm.
//!
//! **Quantized-KV capacity** — the block budget is denominated in
//! f32-sized blocks, so narrower dtypes multiply the enforced block count
//! instead of shrinking memory. Rows record, for an identical tight
//! budget, the effective blocks and the resident sequences each dtype
//! admits (f32 1×, f16 2×, int8 4×) plus the bytes-per-block they pay.
//!
//! With COPRIS_BENCH_JSON set, rows merge idempotently into
//! BENCH_micro.json under the `sampler_simd/` prefix.

use copris::bench::{fmt_secs, merge_bench_rows, render_table, time_fn};
use copris::engine::{
    sample_token_dispatched, Engine, KvCacheConfig, KvDtype, MockBackend, SamplerDispatch,
    SamplerScratch, SamplingParams, WorkItem,
};
use copris::util::json::Obj;
use copris::util::Rng;

fn logits_row(vocab: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    (0..vocab).map(|_| (rng.below(400) as f32 - 200.0) * 0.05).collect()
}

fn item(id: u64, prompt: Vec<i32>) -> WorkItem {
    WorkItem {
        request_id: id,
        prompt: prompt.into(),
        resume: vec![],
        max_total: 96,
        sampling: SamplingParams::greedy(),
        retain: None,
        prefix: None,
    }
}

fn main() {
    let mut table: Vec<Vec<String>> = Vec::new();
    let mut entries: Vec<String> = Vec::new();

    // -- sampler arms ----------------------------------------------------
    let arms = SamplerDispatch::available();
    let params = [
        ("default", SamplingParams::default()),
        ("top-k8 top-p0.9", SamplingParams { temperature: 1.0, top_p: 0.9, top_k: 8 }),
    ];
    let mut scratch = SamplerScratch::new();
    for vocab in [48usize, 512] {
        let logits = logits_row(vocab, 7);
        for (pname, p) in &params {
            // Bit-identity gate: all arms must agree with scalar on this
            // exact workload before any of them gets a timing row.
            let golden: Vec<(i32, u32)> = (0..64)
                .map(|i| {
                    let mut rng = Rng::new(100 + i);
                    let (t, lp) = sample_token_dispatched(
                        &logits,
                        p,
                        &mut rng,
                        &mut scratch,
                        SamplerDispatch::Scalar,
                    );
                    (t, lp.to_bits())
                })
                .collect();
            for &d in &arms {
                let got: Vec<(i32, u32)> = (0..64)
                    .map(|i| {
                        let mut rng = Rng::new(100 + i);
                        let (t, lp) =
                            sample_token_dispatched(&logits, p, &mut rng, &mut scratch, d);
                        (t, lp.to_bits())
                    })
                    .collect();
                assert_eq!(golden, got, "{} diverged from scalar on vocab {vocab}", d.name());

                let mut rng = Rng::new(1);
                let s = time_fn(200, 4000, || {
                    sample_token_dispatched(&logits, p, &mut rng, &mut scratch, d)
                });
                let toks_per_s = 1.0 / s.mean.max(1e-12);
                let name = format!("sampler_simd/{} vocab{vocab} {pname}", d.name());
                table.push(vec![
                    name.clone(),
                    fmt_secs(s.mean),
                    fmt_secs(s.p95),
                    format!("{:.2e}", toks_per_s),
                ]);
                entries.push(
                    Obj::new()
                        .str("path", &name)
                        .num("mean_s", s.mean)
                        .num("p50_s", s.p50)
                        .num("p95_s", s.p95)
                        .int("iters", s.n as i64)
                        .num("tokens_per_s", toks_per_s)
                        .finish(),
                );
            }
        }
    }

    // -- quantized-KV capacity -------------------------------------------
    // Identical tight budget (4 f32 blocks) and workload per dtype; the
    // narrower dtypes admit more resident sequences from the same bytes.
    for dtype in [KvDtype::F32, KvDtype::F16, KvDtype::Int8] {
        let mut be = MockBackend::new(16, 96);
        be.min_len = 60;
        be.spread = 1; // long outputs keep admitted sequences resident
        let kv = KvCacheConfig { budget_blocks: 4, dtype, ..KvCacheConfig::default() };
        let block_bytes = kv.block_bytes();
        let mut eng = Engine::with_kv(0, be, kv, 1);
        for i in 0..16u64 {
            eng.submit(item(i, vec![1, i as i32 % 9 + 1, 9, 9])).unwrap();
        }
        let mut ev = Vec::new();
        let mut resident_peak = 0usize;
        for _ in 0..8 {
            eng.step(&mut ev).unwrap();
            resident_peak = resident_peak.max(eng.busy());
            ev.clear();
        }
        let name = format!("sampler_simd/kv-capacity {}", dtype.name());
        table.push(vec![
            name.clone(),
            format!("{} eff blocks", eng.kv_effective_budget_blocks()),
            format!("{} resident", resident_peak),
            format!("{block_bytes} B/block"),
        ]);
        entries.push(
            Obj::new()
                .str("path", &name)
                .int("budget_blocks", 4)
                .int("effective_blocks", eng.kv_effective_budget_blocks() as i64)
                .int("resident_peak", resident_peak as i64)
                .int("block_bytes", block_bytes as i64)
                .finish(),
        );
    }

    println!("== sampler_simd: dispatch arms + quantized-KV capacity ==");
    println!("detected arm: {}", SamplerDispatch::detect().name());
    println!("{}", render_table(&["path", "mean / eff", "p95 / resident", "rate / bytes"], &table));

    if let Ok(path) = std::env::var("COPRIS_BENCH_JSON") {
        merge_bench_rows(&path, "sampler_simd", "sampler_simd/", &entries);
    }
}
