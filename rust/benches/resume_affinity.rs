//! Resume-affinity bench: replay-only vs KV-retention CoPRIS on the mock
//! backend — measures the replay tokens avoided and the stage wall-clock
//! effect of resuming buffered partials from retained KV instead of
//! re-prefilling them (the paper's §5.4.1 recomputation overhead, which
//! APRIL/Laminar identify as the dominant partial-rollout cost).
//!
//! Arms (greedy sampling, so the replay-comparable arms — all except
//! `retained + stale-kv`, which continues the old-params script across
//! syncs BY DESIGN — generate identical token streams, pinned by
//! tests/retained_golden.rs; their wall delta is exactly the replay decode
//! steps avoided × the per-step decode delay):
//!
//!   replay-only            retention off; every resume re-prefills.
//!   retained               retention on, no syncs between stages — the
//!                          pipelined regime, where stage t+1 resumes
//!                          BEFORE the stage-t sync lands.
//!   replay-only + sync     baseline with a weight sync after every stage
//!                          (the serial rollout → train → sync loop).
//!   retained + sync        retention on, sync each stage: invalidation
//!                          drops every retained slot, so hits ≈ 0 and the
//!                          arm degrades to the replay baseline — the
//!                          sanity row.
//!   retained + stale-kv    `retain_kv_across_sync`: hits survive the sync
//!                          by continuing from stale KV (extra off-policy
//!                          staleness traded for zero recompute).
//!
//! Scale via COPRIS_BENCH_STAGES / COPRIS_BENCH_DECODE_US. With
//! COPRIS_BENCH_JSON set, rows are APPENDED to the existing
//! BENCH_micro.json (scripts/bench_micro.sh runs micro first, then this).

use std::sync::Arc;
use std::time::{Duration, Instant};

use copris::bench::{fmt_secs, merge_bench_rows, render_table};
use copris::config::{Config, RolloutMode};
use copris::coordinator::Coordinator;
use copris::engine::{EnginePool, MockBackend};
use copris::exp::common::env_usize;
use copris::tasks::Dataset;
use copris::util::json::Obj;

const MAX_SEQ: usize = 96;

#[derive(Clone, Debug, Default)]
struct ArmResult {
    wall: f64,
    stage_secs: f64,
    completed: usize,
    resumed: usize,
    replayed_tokens: u64,
    replay_tokens_saved: u64,
    retained_hits: usize,
    retained_misses: usize,
}

struct ArmOpts {
    retain: bool,
    across_sync: bool,
    sync_each_stage: bool,
    stages: usize,
    decode_us: u64,
}

fn run_arm(o: &ArmOpts) -> ArmResult {
    let mut cfg = Config::new("mock");
    cfg.rollout.mode = RolloutMode::Copris;
    cfg.rollout.batch_prompts = 3;
    cfg.rollout.group_size = 2;
    // Over-generation well past B·G so every stage ends with a fat tail of
    // in-flight partials — the material the resume path works on.
    cfg.rollout.concurrency = 10;
    cfg.rollout.temperature = 0.0; // greedy: identical streams across arms
    cfg.rollout.retain_kv = o.retain;
    cfg.rollout.retain_kv_across_sync = o.across_sync;
    cfg.engine.engines = 2;
    cfg.train.seed = 11;
    let slots = 4;
    let decode = Duration::from_micros(o.decode_us);
    let pool = EnginePool::spawn_kv(
        cfg.engine.engines,
        slots,
        cfg.engine.kv_cache_config(),
        cfg.train.seed,
        move |_id| {
            Box::new(move || {
                let mut b = MockBackend::new(slots, MAX_SEQ);
                // Long scripts: partials carry a meaty prefix to resume.
                b.min_len = 24;
                b.spread = 24;
                b.decode_delay = Some(decode);
                Ok(b)
            })
        },
    )
    .expect("spawn pool");
    let mut coord = Coordinator::new(pool, cfg.clone(), MAX_SEQ);
    let mut ds = Dataset::train(cfg.train.seed);

    let mut r = ArmResult::default();
    let t0 = Instant::now();
    for stage in 0..o.stages {
        let out = coord.rollout_stage(&mut ds).expect("stage");
        r.stage_secs += out.stats.wall;
        r.completed += out.stats.completed;
        r.resumed += out.stats.resumed;
        r.replayed_tokens += out.stats.replayed_tokens;
        r.replay_tokens_saved += out.stats.replay_tokens_saved;
        r.retained_hits += out.stats.retained_hits;
        r.retained_misses += out.stats.retained_misses;
        if o.sync_each_stage {
            let v = stage as u64 + 1;
            coord.sync_weights(v, Arc::new(vec![v as f32 * 0.5 + 1.0]));
        }
    }
    r.wall = t0.elapsed().as_secs_f64();
    coord.shutdown();
    r
}

fn main() {
    let stages = env_usize("COPRIS_BENCH_STAGES", 6);
    let decode_us = env_usize("COPRIS_BENCH_DECODE_US", 800) as u64;

    println!(
        "== resume_affinity: replay-only vs KV-retention CoPRIS (mock backend) ==\n\
         {stages} stages, B=3 G=2 N'=10, 2 engines × 4 slots, decode {decode_us}µs/step\n"
    );

    let arms: Vec<(&str, ArmOpts)> = vec![
        (
            "replay-only",
            ArmOpts { retain: false, across_sync: false, sync_each_stage: false, stages, decode_us },
        ),
        (
            "retained",
            ArmOpts { retain: true, across_sync: false, sync_each_stage: false, stages, decode_us },
        ),
        (
            "replay-only + sync",
            ArmOpts { retain: false, across_sync: false, sync_each_stage: true, stages, decode_us },
        ),
        (
            "retained + sync",
            ArmOpts { retain: true, across_sync: false, sync_each_stage: true, stages, decode_us },
        ),
        (
            "retained + stale-kv",
            ArmOpts { retain: true, across_sync: true, sync_each_stage: true, stages, decode_us },
        ),
    ];

    let mut results: Vec<(&str, ArmResult)> = Vec::new();
    for (name, opts) in &arms {
        results.push((*name, run_arm(opts)));
    }

    let baseline_stage = results[0].1.stage_secs;
    let headers = [
        "Arm", "Stage s (sum)", "Speedup", "Completed", "Resumed",
        "Replayed tok", "Saved tok", "KV hits", "KV misses",
    ];
    let rows: Vec<Vec<String>> = results
        .iter()
        .map(|(name, r)| {
            vec![
                name.to_string(),
                format!("{:.3}", r.stage_secs),
                format!("{:.2}x", baseline_stage / r.stage_secs.max(1e-9)),
                r.completed.to_string(),
                r.resumed.to_string(),
                r.replayed_tokens.to_string(),
                r.replay_tokens_saved.to_string(),
                r.retained_hits.to_string(),
                r.retained_misses.to_string(),
            ]
        })
        .collect();
    println!("{}", render_table(&headers, &rows));
    println!(
        "\nexpected shape: the `retained` arm shows replayed tok → 0 with saved tok > 0\n\
         and stage wall ≤ replay-only (the avoided replay decode steps × {decode_us}µs);\n\
         `retained + sync` degrades to the replay baseline (invalidation);\n\
         `retained + stale-kv` keeps the savings across syncs at the cost of extra\n\
         off-policy staleness (IS-corrected via per-segment behaviour log-probs).\n\
         mean stage wall: {}",
        fmt_secs(results[1].1.stage_secs / stages.max(1) as f64),
    );

    // Machine-readable rows appended to BENCH_micro.json.
    if let Ok(path) = std::env::var("COPRIS_BENCH_JSON") {
        let entries: Vec<String> = results
            .iter()
            .map(|(name, r)| {
                Obj::new()
                    .str("path", &format!("resume_affinity {name} (stage wall)"))
                    .num("mean_s", r.stage_secs / stages.max(1) as f64)
                    .num("p50_s", r.stage_secs / stages.max(1) as f64)
                    .num("p95_s", r.stage_secs / stages.max(1) as f64)
                    .int("iters", stages as i64)
                    .int("replayed_tokens", r.replayed_tokens as i64)
                    .int("replay_tokens_saved", r.replay_tokens_saved as i64)
                    .int("retained_hits", r.retained_hits as i64)
                    .int("retained_misses", r.retained_misses as i64)
                    .finish()
            })
            .collect();
        merge_bench_rows(&path, "resume_affinity", "resume_affinity", &entries);
    }
}
