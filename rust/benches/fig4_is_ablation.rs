//! Regenerates paper Fig. 4: the Cross-stage Importance Sampling Correction
//! ablation — w/ IS vs w/o IS eval-score curves at two model scales.

use copris::exp::common::{artifacts_available, env_str, env_usize};
use copris::exp::fig4;

fn main() {
    let models_env = env_str("COPRIS_BENCH_MODELS", "tiny,small");
    let models: Vec<&str> =
        models_env.split(',').filter(|m| artifacts_available(m)).collect();
    if models.is_empty() {
        eprintln!("fig4: no artifacts found — run `make artifacts`");
        return;
    }
    let sft = env_usize("COPRIS_BENCH_SFT", 80);
    let steps = env_usize("COPRIS_BENCH_STEPS", 16);
    let eval_every = env_usize("COPRIS_BENCH_EVAL_EVERY", 4);
    let curves = fig4::run(&models, sft, steps, eval_every).expect("fig4 run");
    println!("{}", fig4::render(&curves));
}
