//! Regenerates paper Table 2: concurrency-level ablation — naive partial
//! rollout vs CoPRIS at swept N′; scores + step/rollout/cal-logprob times
//! + preemption/replay (recomputation) accounting.

use copris::exp::common::{artifacts_available, env_str, env_usize};
use copris::exp::table2;

fn main() {
    let model = env_str("COPRIS_BENCH_MODEL", "small");
    if !artifacts_available(&model) {
        eprintln!("table2: artifacts/{model} missing — run `make artifacts`");
        return;
    }
    let sft = env_usize("COPRIS_BENCH_SFT", 80);
    let steps = env_usize("COPRIS_BENCH_STEPS", 12);
    let rows = table2::run(&model, sft, steps).expect("table2 run");
    println!("{}", table2::render(&rows));
}
