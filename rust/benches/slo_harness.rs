//! SLO harness bench: open-loop lockstep sim scenarios → scoreboard rows.
//!
//! Three **deterministic** scenario rows (steady Poisson, bursty on/off,
//! single-engine overload) run the bit-exact lockstep sim
//! (`loadgen::run_sim`) at a fixed seed and record counter/percentile
//! fields — arrived/shed/completed, tokens, TTFT/ITL/E2E p50/p99 on the
//! virtual clock, goodput, preemption rate, queue depth, rounds. These
//! rows carry `"kind":"deterministic"`: `scripts/bench_check.py` gates
//! them EXACTLY (two fresh runs must agree bit-for-bit), no seeded
//! baseline or tolerance band required. The bench re-runs every scenario
//! in-process and asserts the reports are identical before emitting a
//! row, so a nondeterministic build can never publish one.
//!
//! One **timing** row (`"kind":"timing"`) records the wall cost of a sim
//! run and keeps the legacy ±tolerance treatment.
//!
//! With COPRIS_BENCH_JSON set, rows merge idempotently into
//! BENCH_micro.json under the `slo ` prefix.

use copris::bench::{fmt_secs, merge_bench_rows, render_table, time_fn};
use copris::loadgen::{run_sim, ArrivalProcess, SimConfig, SimResult, TenantMix};
use copris::util::json::Obj;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn scenarios(requests: usize) -> Vec<(&'static str, SimConfig)> {
    vec![
        (
            "poisson steady",
            SimConfig {
                requests,
                seed: 7,
                process: ArrivalProcess::Poisson { rate_rps: 300.0 },
                ..SimConfig::default()
            },
        ),
        (
            "bursty on-off",
            SimConfig {
                requests,
                seed: 7,
                process: ArrivalProcess::Bursty {
                    rate_rps: 300.0,
                    on_ticks: 20_000,
                    off_ticks: 80_000,
                },
                ..SimConfig::default()
            },
        ),
        (
            "overload shed",
            SimConfig {
                engines: 1,
                slots: 2,
                queue_cap: 8,
                requests,
                seed: 7,
                process: ArrivalProcess::Poisson { rate_rps: 4_000.0 },
                mix: TenantMix::default_mix(0.3),
                ..SimConfig::default()
            },
        ),
    ]
}

fn scenario_row(name: &str, cfg: &SimConfig, r: &SimResult) -> String {
    let rep = &r.report;
    Obj::new()
        .str("path", &format!("slo {name}"))
        .str("kind", "deterministic")
        .str("process", cfg.process.name())
        .int("arrived", rep.arrived as i64)
        .int("shed", rep.shed as i64)
        .int("completed", rep.completed as i64)
        .int("completed_interactive", rep.completed_interactive as i64)
        .int("completed_bulk", rep.completed_bulk as i64)
        .int("tokens_out", rep.tokens_out as i64)
        .num("ttft_p50_ticks", rep.ttft_p50_ticks)
        .num("ttft_p99_ticks", rep.ttft_p99_ticks)
        .num("itl_p50_ticks", rep.itl_p50_ticks)
        .num("itl_p99_ticks", rep.itl_p99_ticks)
        .num("e2e_p50_ticks", rep.e2e_p50_ticks)
        .num("e2e_p99_ticks", rep.e2e_p99_ticks)
        .num("goodput_rps", rep.goodput_rps)
        .num("shed_rate", rep.shed_rate)
        .num("preemption_rate", rep.preemption_rate)
        .int("preemptions", rep.preemptions as i64)
        .int("queue_depth_peak", rep.queue_depth_peak as i64)
        .int("rounds", r.rounds as i64)
        .int("end_tick", r.end_tick as i64)
        .finish()
}

fn main() {
    let requests = env_usize("SLO_REQUESTS", 200);
    let mut table: Vec<Vec<String>> = Vec::new();
    let mut entries: Vec<String> = Vec::new();

    for (name, cfg) in scenarios(requests) {
        let a = run_sim(&cfg);
        // Replay gate: a scenario only gets a deterministic row if the
        // same config replays bit-identically in this very process.
        let b = run_sim(&cfg);
        assert_eq!(a.report, b.report, "sim nondeterminism in scenario {name:?}");
        assert_eq!((a.rounds, a.end_tick), (b.rounds, b.end_tick), "{name:?}");
        assert!(a.completed_all, "scenario {name:?} tripped the livelock valve");
        let rep = &a.report;
        table.push(vec![
            format!("slo {name}"),
            format!("{}/{}/{}", rep.arrived, rep.completed, rep.shed),
            format!("{:.0}/{:.0}", rep.ttft_p50_ticks, rep.ttft_p99_ticks),
            format!("{:.0}/{:.0}", rep.itl_p50_ticks, rep.itl_p99_ticks),
            format!("{:.2}", rep.goodput_rps),
            format!("{:.3}", rep.preemption_rate),
        ]);
        entries.push(scenario_row(name, &cfg, &a));
    }

    // Timing row: wall cost of one steady-Poisson sim run (legacy ±band).
    let (_, timing_cfg) = scenarios(requests.min(100)).swap_remove(0);
    let s = time_fn(2, 12, || run_sim(&timing_cfg));
    table.push(vec![
        "slo sim wall (poisson)".to_string(),
        String::new(),
        fmt_secs(s.mean),
        fmt_secs(s.p95),
        String::new(),
        String::new(),
    ]);
    entries.push(
        Obj::new()
            .str("path", "slo sim wall (poisson)")
            .str("kind", "timing")
            .num("mean_s", s.mean)
            .num("p50_s", s.p50)
            .num("p95_s", s.p95)
            .int("iters", s.n as i64)
            .finish(),
    );

    println!("== slo_harness: open-loop scenarios → SLO scoreboard ==");
    println!(
        "{}",
        render_table(
            &["path", "arr/done/shed", "ttft p50/p99", "itl p50/p99", "goodput", "preempt"],
            &table
        )
    );

    if let Ok(path) = std::env::var("COPRIS_BENCH_JSON") {
        merge_bench_rows(&path, "slo_harness", "slo ", &entries);
    }
}
