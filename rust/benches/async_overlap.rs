//! Serial vs stage-pipelined vs fully-async CoPRIS, end-to-end wall clock
//! at equal batch count on the mock backend. The mock's per-step decode
//! delay stands in for GPU decode time; the simulated trainer window
//! stands in for cal-logprob → grad → update. The async arm never
//! quiesces the stream: batch boundaries cost a `take` + bounded-staleness
//! cut instead of a full drain + cold refill, so its wall clock should sit
//! at or below the pipelined arm's, with the staleness/active cut counts
//! showing the protocol at work.
//!
//! Scale via COPRIS_BENCH_STEPS / COPRIS_BENCH_TRAIN_MS /
//! COPRIS_BENCH_DECODE_US / COPRIS_BENCH_STALENESS. With
//! COPRIS_BENCH_JSON set, rows are merged into the existing
//! BENCH_micro.json (scripts/bench_micro.sh runs micro first, then this).

use std::time::Duration;

use copris::bench::{merge_bench_rows, render_table};
use copris::config::ExecMode;
use copris::exp::common::env_usize;
use copris::exp::pipesim::{run_mode, PipeSimOpts, PipeSimSummary};
use copris::util::json::Obj;

fn main() {
    let mut opts = PipeSimOpts::default();
    opts.steps = env_usize("COPRIS_BENCH_STEPS", 8);
    opts.train_secs = env_usize("COPRIS_BENCH_TRAIN_MS", 60) as f64 / 1e3;
    opts.decode_delay =
        Duration::from_micros(env_usize("COPRIS_BENCH_DECODE_US", 1000) as u64);
    opts.cfg.rollout.max_staleness = env_usize("COPRIS_BENCH_STALENESS", 1);
    opts.cfg.rollout.execution = ExecMode::Async;

    println!(
        "== async_overlap: serial vs pipelined vs fully-async CoPRIS (mock backend) ==\n\
         {} steps, B={} G={} N'={}, decode {:?}/step, simulated train {:.0}ms/step, S={}\n",
        opts.steps,
        opts.cfg.rollout.batch_prompts,
        opts.cfg.rollout.group_size,
        opts.cfg.rollout.concurrency,
        opts.decode_delay,
        opts.train_secs * 1e3,
        opts.cfg.rollout.max_staleness,
    );

    let (serial, _) = run_mode(&opts, ExecMode::Serial).expect("serial arm");
    let (piped, _) = run_mode(&opts, ExecMode::Pipelined).expect("pipelined arm");
    let (asynch, _) = run_mode(&opts, ExecMode::Async).expect("async arm");

    let headers = [
        "Arm", "Wall s", "Groups", "Samples", "Overlap s", "Lagged trajs",
        "Stale cuts", "Active cuts", "Speedup",
    ];
    let row = |name: &str, s: &PipeSimSummary, speedup: f64| {
        vec![
            name.to_string(),
            format!("{:.2}", s.wall),
            s.groups.to_string(),
            s.samples.to_string(),
            format!("{:.2}", s.overlap_secs),
            s.lagged_trajectories.to_string(),
            s.staleness_terminations.to_string(),
            s.active_terminations.to_string(),
            if speedup > 0.0 { format!("{speedup:.2}x") } else { "-".into() },
        ]
    };
    let rows = vec![
        row("serial copris", &serial, 0.0),
        row("pipelined copris", &piped, serial.wall / piped.wall.max(1e-9)),
        row("async copris", &asynch, serial.wall / asynch.wall.max(1e-9)),
    ];
    println!("{}", render_table(&headers, &rows));
    println!(
        "\nexpected shape: async wall ≤ pipelined wall ≤ serial wall at equal batches;\n\
         async batch boundaries cut only over-staleness work (stale/active cuts > 0\n\
         at small S) instead of draining the whole stream."
    );

    // Machine-readable rows merged into BENCH_micro.json.
    if let Ok(path) = std::env::var("COPRIS_BENCH_JSON") {
        let entries: Vec<String> = [
            ("serial", &serial),
            ("pipelined", &piped),
            ("async", &asynch),
        ]
        .iter()
        .map(|(name, s)| {
            Obj::new()
                .str("path", &format!("async_overlap {name} (run wall)"))
                .num("mean_s", s.wall / opts.steps.max(1) as f64)
                .num("p50_s", s.wall / opts.steps.max(1) as f64)
                .num("p95_s", s.wall / opts.steps.max(1) as f64)
                .int("iters", opts.steps as i64)
                .num("overlap_s", s.overlap_secs)
                .int("lagged_trajs", s.lagged_trajectories as i64)
                .int("staleness_terminations", s.staleness_terminations as i64)
                .int("active_terminations", s.active_terminations as i64)
                .finish()
        })
        .collect();
        merge_bench_rows(&path, "async_overlap", "async_overlap", &entries);
    }
}
