//! Chaos tests for the fault-tolerant engine pool: supervised engine
//! threads + coordinator recovery, driven by the deterministic
//! fault-injection harness (`testkit::faulty`).
//!
//! The golden oracle: a stage that loses an engine mid-flight (crash,
//! panic, or stall caught by the watchdog) must recover on the survivors
//! and produce the SAME final trajectory set as a fault-free run — same
//! per-request token streams, modulo engine assignment. That holds
//! because mock token streams are scripted purely by (prompt,
//! params_epoch) and re-dispatch resumes from the coordinator-side
//! trajectory (the same replay path a buffered partial takes), so which
//! engine executes a request never changes its tokens.

use std::io::{BufRead, BufReader};
use std::net::TcpListener;
use std::process::{Command, Stdio};
use std::sync::Arc;
use std::time::{Duration, Instant};

use copris::config::{Config, ExecMode, RolloutMode, TransportKind};
use copris::coordinator::{Coordinator, OpenLoopRequest, RolloutOutput};
use copris::engine::{EnginePool, MockBackend, SamplingParams};
use copris::loadgen::{ArrivalGen, ArrivalProcess, TenantMix};
use copris::net::host::{serve, HostBackend, HostConfig};
use copris::router::RouterPool;
use copris::tasks::Dataset;
use copris::testkit::faulty::{FaultKind, FaultOp, FaultPlan, FaultyBackend};
use copris::util::Rng;
use copris::{prop_assert, prop_assert_eq};

const MAX_SEQ: usize = 96;

fn chaos_cfg(mode: RolloutMode) -> Config {
    let mut cfg = Config::new("mock");
    cfg.rollout.mode = mode;
    cfg.rollout.batch_prompts = 3;
    cfg.rollout.group_size = 2;
    cfg.rollout.concurrency = 4;
    cfg.rollout.temperature = 0.0; // greedy → streams scripted, no RNG
    cfg.engine.engines = 2;
    cfg.engine.retry_backoff_ms = 0;
    cfg.train.seed = 5;
    cfg
}

/// Pool where engine `target` runs the fault script and every other
/// engine is clean (all wrapped in `FaultyBackend` so the backend type —
/// and thus the fault-free baseline — is identical).
fn spawn_faulty(
    cfg: &Config,
    slots: usize,
    min_len: usize,
    spread: usize,
    target: usize,
    plans: Vec<FaultPlan>,
) -> EnginePool {
    EnginePool::spawn_supervised(
        cfg.engine.engines,
        slots,
        cfg.engine.engine_opts(),
        cfg.engine.supervisor_opts(),
        cfg.train.seed,
        move |id| {
            let plans = if id == target { plans.clone() } else { Vec::new() };
            Box::new(move || {
                let mut b = MockBackend::new(slots, MAX_SEQ);
                b.min_len = min_len;
                b.spread = spread;
                Ok(FaultyBackend::new(b, plans))
            })
        },
    )
    .unwrap()
}

/// Canonical stage fingerprint, invariant to completion order, trajectory
/// ids, and engine assignment: groups sorted by task prompt; per group
/// the sorted multiset of (token stream, behaviour-logprob bits).
type Fingerprint = Vec<(String, usize, Vec<(Vec<i32>, Vec<u32>)>)>;

fn fingerprint(out: &RolloutOutput) -> Fingerprint {
    let mut groups: Vec<_> = out
        .groups
        .iter()
        .map(|g| {
            let mut streams: Vec<(Vec<i32>, Vec<u32>)> = g
                .done
                .iter()
                .map(|t| {
                    (
                        t.tokens.clone(),
                        t.behavior_logprobs().iter().map(|l| l.to_bits()).collect(),
                    )
                })
                .collect();
            streams.sort();
            (g.task.prompt.clone(), g.target, streams)
        })
        .collect();
    groups.sort();
    groups
}

fn fault_free_fingerprint(cfg: &Config, slots: usize, min_len: usize, spread: usize) -> Fingerprint {
    let pool = spawn_faulty(cfg, slots, min_len, spread, 1, vec![]);
    let mut base = Coordinator::new(pool, cfg.clone(), MAX_SEQ);
    let mut ds = Dataset::train(cfg.train.seed);
    let out = base.rollout_stage(&mut ds).unwrap();
    assert_eq!(out.stats.engine_failures, 0);
    assert_eq!(out.stats.redispatched_trajectories, 0);
    let fp = fingerprint(&out);
    base.shutdown();
    fp
}

/// THE chaos acceptance check: engine 1 dies on its 2nd decode mid-stage;
/// the stage completes on the survivor with the exact fault-free
/// trajectory set, and the failure/re-dispatch stats record the event.
#[test]
fn crashed_engine_mid_stage_same_final_trajectories() {
    let cfg = chaos_cfg(RolloutMode::Sync);
    let want = fault_free_fingerprint(&cfg, 2, 6, 8);

    let plans = vec![FaultPlan { op: FaultOp::Decode, at_call: 2, kind: FaultKind::Fatal }];
    let mut coord =
        Coordinator::new(spawn_faulty(&cfg, 2, 6, 8, 1, plans), cfg.clone(), MAX_SEQ);
    let mut ds = Dataset::train(cfg.train.seed);
    let out = coord.rollout_stage(&mut ds).unwrap();
    assert_eq!(fingerprint(&out), want, "recovered stage diverged from fault-free run");
    assert_eq!(out.stats.engine_failures, 1, "{:?}", out.stats);
    assert!(out.stats.redispatched_trajectories > 0, "{:?}", out.stats);
    coord.shutdown();
}

/// Same oracle for a panicking backend (the `catch_unwind` supervisor
/// path): a panic mid-decode is one engine failure, not a lost stage.
#[test]
fn panicking_engine_mid_stage_same_final_trajectories() {
    let cfg = chaos_cfg(RolloutMode::Sync);
    let want = fault_free_fingerprint(&cfg, 2, 6, 8);

    let plans = vec![FaultPlan { op: FaultOp::Decode, at_call: 2, kind: FaultKind::Panic }];
    let mut coord =
        Coordinator::new(spawn_faulty(&cfg, 2, 6, 8, 1, plans), cfg.clone(), MAX_SEQ);
    let mut ds = Dataset::train(cfg.train.seed);
    let out = coord.rollout_stage(&mut ds).unwrap();
    assert_eq!(fingerprint(&out), want, "recovered stage diverged from fault-free run");
    assert_eq!(out.stats.engine_failures, 1, "{:?}", out.stats);
    assert!(out.stats.redispatched_trajectories > 0, "{:?}", out.stats);
    coord.shutdown();
}

/// Watchdog oracle: an engine that silently stops producing events (no
/// crash, no event) is declared dead after `engine.stall_timeout_ms` and
/// its work completes on the survivor — same fault-free trajectory set.
/// The stalled engine later wakes up and delivers its backlog; the
/// coordinator must discard those late events, not double-count them.
#[test]
fn stalled_engine_watchdog_same_final_trajectories() {
    let mut cfg = chaos_cfg(RolloutMode::Sync);
    cfg.engine.stall_timeout_ms = 300;
    let want = fault_free_fingerprint(&cfg, 2, 6, 8);

    let plans =
        vec![FaultPlan { op: FaultOp::Decode, at_call: 2, kind: FaultKind::Stall { ms: 1500 } }];
    let mut coord =
        Coordinator::new(spawn_faulty(&cfg, 2, 6, 8, 1, plans), cfg.clone(), MAX_SEQ);
    let mut ds = Dataset::train(cfg.train.seed);
    let out = coord.rollout_stage(&mut ds).unwrap();
    assert_eq!(fingerprint(&out), want, "watchdog recovery diverged from fault-free run");
    assert_eq!(out.stats.engine_failures, 1, "{:?}", out.stats);
    assert!(out.stats.redispatched_trajectories > 0, "{:?}", out.stats);
    coord.shutdown();
}

/// Degraded mode: losing EVERY engine is a structured error from
/// `rollout_stage` — never a hang, never a panic.
#[test]
fn all_engines_lost_is_a_structured_error() {
    let mut cfg = chaos_cfg(RolloutMode::Sync);
    cfg.engine.engines = 1;
    let plans = vec![FaultPlan { op: FaultOp::Decode, at_call: 2, kind: FaultKind::Fatal }];
    let mut coord =
        Coordinator::new(spawn_faulty(&cfg, 2, 6, 8, 0, plans), cfg.clone(), MAX_SEQ);
    let mut ds = Dataset::train(cfg.train.seed);
    let err = coord.rollout_stage(&mut ds).unwrap_err();
    assert!(format!("{err:#}").contains("degraded"), "{err:#}");
    coord.shutdown();
}

/// Transient errors are retried in place within the supervisor budget:
/// no engine failure, no re-dispatch, bit-identical streams, and the
/// retry count surfaces in the stage stats.
#[test]
fn transient_faults_recover_in_place_bit_exact() {
    let cfg = chaos_cfg(RolloutMode::Sync); // max_retries 3, backoff 0
    let want = fault_free_fingerprint(&cfg, 2, 6, 8);

    let plans = vec![FaultPlan {
        op: FaultOp::Decode,
        at_call: 2,
        kind: FaultKind::Transient { times: 2 },
    }];
    let mut coord =
        Coordinator::new(spawn_faulty(&cfg, 2, 6, 8, 1, plans), cfg.clone(), MAX_SEQ);
    let mut ds = Dataset::train(cfg.train.seed);
    let out = coord.rollout_stage(&mut ds).unwrap();
    assert_eq!(fingerprint(&out), want, "transient retry changed the streams");
    assert_eq!(out.stats.engine_failures, 0, "{:?}", out.stats);
    assert_eq!(out.stats.redispatched_trajectories, 0, "{:?}", out.stats);
    assert!(out.stats.retries >= 2, "{:?}", out.stats);
    coord.shutdown();
}

/// `retain_slot` failures at flush must be counted (`retain_errors`), not
/// swallowed — and must NOT kill the engine: the partial is flushed
/// plainly and the stage completes.
#[test]
fn retain_slot_errors_are_counted_not_fatal() {
    let mut cfg = chaos_cfg(RolloutMode::Copris);
    cfg.rollout.batch_prompts = 2;
    cfg.rollout.concurrency = 8;
    cfg.rollout.retain_kv = true;
    cfg.engine.engines = 1;
    cfg.train.seed = 7;
    let plans = vec![FaultPlan { op: FaultOp::RetainSlot, at_call: 1, kind: FaultKind::Fatal }];
    // Long scripts → busy slots at early termination → retain attempts.
    let mut coord =
        Coordinator::new(spawn_faulty(&cfg, 4, 20, 30, 0, plans), cfg.clone(), MAX_SEQ);
    let mut ds = Dataset::train(cfg.train.seed);
    let out = coord.rollout_stage(&mut ds).unwrap();
    assert_eq!(out.stats.engine_failures, 0, "{:?}", out.stats);
    assert!(out.stats.retain_errors > 0, "retain failure not counted: {:?}", out.stats);
    coord.shutdown();
}

/// Seeded Poisson open-loop schedule from the loadgen primitives, with
/// prompts clamped under the MockBackend prompt cap.
fn poisson_schedule(n: usize, rate_rps: f64, seed: u64) -> Vec<OpenLoopRequest> {
    let mut arrivals = ArrivalGen::new(ArrivalProcess::Poisson { rate_rps }, seed);
    let mix = TenantMix::default_mix(0.5);
    let mut rng = Rng::new(seed ^ 0xAB_CD);
    (0..n)
        .map(|i| {
            let arrival_tick = arrivals.next_arrival();
            let spec = mix.sample(&mut rng);
            let plen = spec.prompt_len.min(20); // MockBackend p_max is 24
            let prompt: Vec<i32> = (0..plen).map(|t| 1 + ((i + t) % 9) as i32).collect();
            OpenLoopRequest { arrival_tick, class: spec.class, prompt, out_len: spec.out_len }
        })
        .collect()
}

/// Chaos × open-loop: an engine dies mid-overload under seeded Poisson
/// load through `run_open_loop`. The run must conserve every arrival
/// (completed + shed = arrived, no trajectory lost or duplicated — the
/// collector itself panics on a double finish), absorb the failure via
/// re-dispatch onto the survivor, keep the bounded queue shedding
/// instead of deadlocking, and still emit a complete SLO row (finite
/// positive e2e percentiles, goodput, queue gauge).
#[test]
fn engine_crash_under_open_loop_overload_conserves_and_reports() {
    let mut cfg = chaos_cfg(RolloutMode::Sync);
    cfg.rollout.concurrency = 6;
    let plans = vec![FaultPlan { op: FaultOp::Decode, at_call: 3, kind: FaultKind::Fatal }];
    let mut coord =
        Coordinator::new(spawn_faulty(&cfg, 2, 6, 8, 1, plans), cfg.clone(), MAX_SEQ);
    let schedule = poisson_schedule(40, 2_000.0, 11);
    let out = coord.run_open_loop(&schedule, 4, 1_000, SamplingParams::greedy()).unwrap();

    assert_eq!(out.stats.engine_failures, 1, "{:?}", out.stats);
    assert!(out.stats.redispatched_trajectories > 0, "{:?}", out.stats);

    // Conservation across the failure.
    assert_eq!(out.report.arrived, 40);
    assert_eq!(
        out.report.completed + out.report.shed,
        out.report.arrived,
        "arrivals lost under engine failure: {:?}",
        out.report
    );
    assert!(out.report.shed > 0, "sustained overload over a 4-deep queue must shed");
    assert!(out.report.queue_depth_peak <= 4, "queue bound violated: {:?}", out.report);

    // One complete single-sample group per completed request, ids unique.
    assert_eq!(out.groups.len(), out.report.completed);
    let mut ids: Vec<u64> =
        out.groups.iter().flat_map(|g| g.done.iter().map(|t| t.id)).collect();
    assert_eq!(ids.len(), out.report.completed, "groups must hold exactly one done each");
    let n = ids.len();
    ids.sort_unstable();
    ids.dedup();
    assert_eq!(ids.len(), n, "a trajectory was delivered twice");

    // The SLO row survives the failure: e2e percentiles on the virtual
    // clock, goodput over the horizon. (TTFT/ITL stay 0 on this path —
    // the threaded pool only sees tokens at completion.)
    assert!(
        out.report.e2e_p50_ticks.is_finite() && out.report.e2e_p50_ticks > 0.0,
        "{:?}",
        out.report
    );
    assert!(out.report.e2e_p99_ticks >= out.report.e2e_p50_ticks);
    assert!(out.report.goodput_rps > 0.0);
    assert!(out.report.horizon_ticks > 0);
    coord.shutdown();
}

/// Fault-free open-loop sanity on the threaded pool: light load, nothing
/// shed, every request completes exactly once, and the stage leaves the
/// coordinator clean enough to run a normal training stage afterwards.
#[test]
fn open_loop_then_training_stage_shares_the_coordinator() {
    let cfg = chaos_cfg(RolloutMode::Sync);
    let mut coord =
        Coordinator::new(spawn_faulty(&cfg, 2, 6, 8, 1, vec![]), cfg.clone(), MAX_SEQ);
    let schedule = poisson_schedule(12, 100.0, 3);
    let out = coord.run_open_loop(&schedule, 64, 1_000, SamplingParams::greedy()).unwrap();
    assert_eq!(out.report.arrived, 12);
    assert_eq!(out.report.shed, 0, "light load must not shed: {:?}", out.report);
    assert_eq!(out.report.completed, 12);
    assert_eq!(out.stats.engine_failures, 0);

    // The open-loop stage must not leak driver/inflight/override state
    // into a subsequent closed-loop training stage.
    let mut ds = Dataset::train(cfg.train.seed);
    let trained = coord.rollout_stage(&mut ds).unwrap();
    assert_eq!(trained.groups.len(), cfg.rollout.batch_prompts);
    coord.shutdown();
}

/// Property: for a fault (fatal / panic / transient) injected at a swept
/// call index of a swept op, in sync and CoPRIS modes (with and without
/// KV retention), no trajectory is ever lost or duplicated — sync
/// delivers exactly the dispatched id set; CoPRIS harvests complete
/// groups with every done id unique across stages.
#[test]
fn fault_sweep_no_trajectory_lost_or_duplicated() {
    #[derive(Debug)]
    struct Case {
        mode: u64,
        op: FaultOp,
        kind: u64,
        at_call: usize,
    }
    copris::testkit::prop_check(
        "fault-sweep",
        10,
        |rng| Case {
            mode: rng.below(3),
            op: if rng.below(2) == 0 { FaultOp::Decode } else { FaultOp::Prefill },
            kind: rng.below(3),
            at_call: 1 + rng.below(10) as usize,
        },
        |c| {
            let mut cfg = chaos_cfg(if c.mode == 0 {
                RolloutMode::Sync
            } else {
                RolloutMode::Copris
            });
            cfg.rollout.retain_kv = c.mode == 2;
            let kind = match c.kind {
                0 => FaultKind::Fatal,
                1 => FaultKind::Panic,
                _ => FaultKind::Transient { times: 2 },
            };
            let plans = vec![FaultPlan { op: c.op, at_call: c.at_call, kind }];
            let mut coord =
                Coordinator::new(spawn_faulty(&cfg, 2, 4, 6, 1, plans), cfg.clone(), MAX_SEQ);
            let mut ds = Dataset::train(cfg.train.seed);
            let stages = if c.mode == 0 { 1 } else { 2 };
            let mut seen_ids: Vec<u64> = Vec::new();
            for stage in 0..stages {
                let out = coord
                    .rollout_stage(&mut ds)
                    .map_err(|e| format!("stage {stage} failed: {e:#}"))?;
                prop_assert_eq!(out.groups.len(), cfg.rollout.batch_prompts);
                for g in &out.groups {
                    prop_assert!(
                        g.done.len() >= cfg.rollout.group_size,
                        "incomplete group harvested: {} < {}",
                        g.done.len(),
                        cfg.rollout.group_size
                    );
                    for t in &g.done {
                        prop_assert!(t.complete && t.invariant_ok(), "bad trajectory {}", t.id);
                        seen_ids.push(t.id);
                    }
                }
            }
            let n = seen_ids.len();
            seen_ids.sort_unstable();
            seen_ids.dedup();
            prop_assert_eq!(seen_ids.len(), n); // no id delivered twice
            if c.mode == 0 {
                // Sync: exactly the B·G dispatched ids, none lost.
                let want: Vec<u64> = (0..(cfg.rollout.batch_prompts
                    * cfg.rollout.group_size) as u64)
                    .collect();
                prop_assert_eq!(seen_ids, want);
            }
            coord.shutdown();
            Ok(())
        },
    );
}

/// Fully-async stream chaos (tentpole acceptance): an engine dies mid-
/// stream. The stream must keep delivering exact-B batches of complete
/// groups on the survivor with no trajectory lost or duplicated (every
/// done id and group id unique across the whole stream), the failure
/// recorded in the window stats, and the bounded-staleness invariant
/// intact throughout the recovery.
#[test]
fn crashed_engine_mid_async_stream_conserves_trajectories() {
    let mut cfg = chaos_cfg(RolloutMode::Copris);
    cfg.rollout.execution = ExecMode::Async;
    cfg.rollout.max_staleness = 1;
    let plans = vec![FaultPlan { op: FaultOp::Decode, at_call: 6, kind: FaultKind::Fatal }];
    let mut coord = Coordinator::new(spawn_faulty(&cfg, 2, 6, 8, 1, plans), cfg.clone(), MAX_SEQ);
    coord.sync_weights(1, Arc::new(vec![1.0f32]));
    let mut ds = Dataset::train(cfg.train.seed);
    coord.begin_async(&mut ds).unwrap();
    let (b, g) = (cfg.rollout.batch_prompts, cfg.rollout.group_size);
    let mut seen_groups: Vec<u64> = Vec::new();
    let mut seen_ids: Vec<u64> = Vec::new();
    let mut failures = 0usize;
    for version in 2..6u64 {
        while !coord
            .pump_async(&mut ds, Instant::now() + Duration::from_secs(60))
            .unwrap()
        {}
        let out = coord.take_async_batch().unwrap();
        assert_eq!(out.groups.len(), b, "exact-B delivery under chaos");
        for grp in &out.groups {
            assert!(grp.done.len() >= g, "incomplete group harvested");
            seen_groups.push(grp.group_id);
            for t in &grp.done {
                assert!(t.complete && t.invariant_ok(), "bad trajectory {}", t.id);
                for seg in &t.segments {
                    assert!(seg.staleness() <= 1, "staleness bound violated under chaos");
                }
                seen_ids.push(t.id);
            }
        }
        failures += out.stats.engine_failures;
        coord.prepare_sync(version).unwrap();
        coord.sync_weights(version, Arc::new(vec![1.0f32]));
        coord.resume_refill(&mut ds).unwrap();
    }
    assert!(failures >= 1, "injected fault never fired");
    for ids in [&mut seen_groups, &mut seen_ids] {
        let n = ids.len();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), n, "a group or trajectory was delivered twice");
    }
    coord.abort_stage().unwrap();
    coord.shutdown();
}

// ---------------------------------------------------------------------------
// Engine-host (multi-process transport) chaos: a killed HOST must land in
// the exact same `EngineFailed` → re-dispatch recovery path an in-process
// engine crash takes, with the same fault-free golden oracle.
// ---------------------------------------------------------------------------

/// In-test engine-host thread serving one router connection on loopback,
/// mock knobs matching `spawn_faulty`'s. With `crash_after`, the host
/// severs its socket after forwarding exactly that many event frames —
/// the deterministic "host died mid-stage".
fn spawn_crash_host(
    cfg: &Config,
    slots: usize,
    min_len: usize,
    spread: usize,
    crash_after: Option<u64>,
) -> (String, std::thread::JoinHandle<()>) {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let hc = HostConfig {
        engines: 1,
        slots,
        engine_opts: cfg.engine.engine_opts(),
        sup: cfg.engine.supervisor_opts(),
        backend: HostBackend::Mock {
            min_len,
            spread,
            decode_delay_us: 0,
            max_seq: MAX_SEQ,
        },
        crash_after_events: crash_after,
        crash_exit: false,
    };
    let thread = std::thread::spawn(move || {
        let _ = serve(listener, hc, true);
    });
    (addr, thread)
}

/// Build a 2-host tcp-transport coordinator: host 0 healthy, host 1
/// (replica id 1, matching the in-process chaos target) optionally rigged
/// to die after `crash_after` event frames.
fn two_host_coordinator(
    cfg: &Config,
    slots: usize,
    min_len: usize,
    spread: usize,
    crash_after: Option<u64>,
) -> (Coordinator, Vec<std::thread::JoinHandle<()>>) {
    let (a_addr, a_thread) = spawn_crash_host(cfg, slots, min_len, spread, None);
    let (b_addr, b_thread) = spawn_crash_host(cfg, slots, min_len, spread, crash_after);
    let mut cfg = cfg.clone();
    cfg.router.transport = TransportKind::Tcp;
    cfg.router.hosts = format!("{a_addr},{b_addr}");
    let pool = RouterPool::connect(&cfg.router, cfg.train.seed).unwrap();
    assert_eq!(pool.engines(), 2);
    (Coordinator::new(pool, cfg, MAX_SEQ), vec![a_thread, b_thread])
}

/// The killed-host analogue of `crashed_engine_mid_stage...`: the host
/// carrying replica 1 severs its link after 2 event frames mid-stage; the
/// link loss synthesizes `EngineFailed`, recovery completes the stage on
/// the surviving host, and the trajectory set matches the fault-free
/// in-process golden bit-for-bit.
#[test]
fn killed_engine_host_mid_stage_same_final_trajectories() {
    let cfg = chaos_cfg(RolloutMode::Sync);
    let want = fault_free_fingerprint(&cfg, 2, 6, 8);

    let (mut coord, hosts) = two_host_coordinator(&cfg, 2, 6, 8, Some(2));
    let mut ds = Dataset::train(cfg.train.seed);
    let out = coord.rollout_stage(&mut ds).unwrap();
    assert_eq!(fingerprint(&out), want, "host-kill recovery diverged from fault-free run");
    assert!(out.stats.engine_failures >= 1, "{:?}", out.stats);
    assert!(out.stats.redispatched_trajectories > 0, "{:?}", out.stats);
    coord.shutdown();
    for h in hosts {
        h.join().unwrap();
    }
}

/// Killed host × open loop: a host dies under seeded Poisson overload
/// through `run_open_loop` over the tcp transport. Every arrival is
/// conserved (completed + shed = arrived), the failure is absorbed via
/// re-dispatch, the bounded queue keeps shedding, and the SLO row is
/// complete — the same contract `engine_crash_under_open_loop...` pins
/// for the in-process pool.
#[test]
fn killed_engine_host_mid_open_loop_conserves_and_reports() {
    let mut cfg = chaos_cfg(RolloutMode::Sync);
    cfg.rollout.concurrency = 6;
    let (mut coord, hosts) = two_host_coordinator(&cfg, 2, 6, 8, Some(3));
    let schedule = poisson_schedule(40, 2_000.0, 11);
    let out = coord.run_open_loop(&schedule, 4, 1_000, SamplingParams::greedy()).unwrap();

    assert!(out.stats.engine_failures >= 1, "{:?}", out.stats);
    assert!(out.stats.redispatched_trajectories > 0, "{:?}", out.stats);
    assert_eq!(out.report.arrived, 40);
    assert_eq!(
        out.report.completed + out.report.shed,
        out.report.arrived,
        "arrivals lost under host failure: {:?}",
        out.report
    );
    assert!(out.report.queue_depth_peak <= 4, "queue bound violated: {:?}", out.report);
    assert_eq!(out.groups.len(), out.report.completed);
    let mut ids: Vec<u64> = out.groups.iter().flat_map(|g| g.done.iter().map(|t| t.id)).collect();
    let n = ids.len();
    ids.sort_unstable();
    ids.dedup();
    assert_eq!(ids.len(), n, "a trajectory was delivered twice");
    assert!(
        out.report.e2e_p50_ticks.is_finite() && out.report.e2e_p50_ticks > 0.0,
        "{:?}",
        out.report
    );
    coord.shutdown();
    for h in hosts {
        h.join().unwrap();
    }
}

/// Full-fidelity host kill: a REAL `copris engine-host` subprocess rigged
/// with `--crash-after-events` dies (exit code 9) mid-stage; the stage
/// recovers onto a surviving host with the fault-free trajectory set.
/// Runs only under `cargo test` (needs the binary); self-skips otherwise.
#[test]
fn killed_engine_host_subprocess_same_final_trajectories() {
    let Some(bin) = option_env!("CARGO_BIN_EXE_copris") else {
        eprintln!("skipping: copris binary path not provided by cargo");
        return;
    };
    let cfg = chaos_cfg(RolloutMode::Sync);
    let want = fault_free_fingerprint(&cfg, 2, 6, 8);

    let mut child = Command::new(bin)
        .args([
            "engine-host",
            "--listen",
            "127.0.0.1:0",
            "--engines",
            "1",
            "--slots",
            "2",
            "--backend",
            "mock",
            "--mock-min-len",
            "6",
            "--mock-spread",
            "8",
            "--crash-after-events",
            "2",
            "--once",
        ])
        .stdout(Stdio::piped())
        .stderr(Stdio::null())
        .spawn()
        .expect("spawning copris engine-host");
    let mut line = String::new();
    BufReader::new(child.stdout.take().unwrap()).read_line(&mut line).unwrap();
    let Some(child_addr) = line.trim().strip_prefix("engine-host listening on ") else {
        let _ = child.kill();
        panic!("engine-host did not announce its address: {line:?}");
    };

    // Healthy thread-host first → replica 0 survives; subprocess is
    // replica 1 and dies after 2 event frames.
    let (a_addr, a_thread) = spawn_crash_host(&cfg, 2, 6, 8, None);
    let mut cfg = cfg.clone();
    cfg.router.transport = TransportKind::Tcp;
    cfg.router.hosts = format!("{a_addr},{child_addr}");
    let pool = RouterPool::connect(&cfg.router, cfg.train.seed).unwrap();
    let mut coord = Coordinator::new(pool, cfg.clone(), MAX_SEQ);

    let mut ds = Dataset::train(cfg.train.seed);
    let out = coord.rollout_stage(&mut ds).unwrap();
    assert_eq!(fingerprint(&out), want, "subprocess-kill recovery diverged");
    assert!(out.stats.engine_failures >= 1, "{:?}", out.stats);
    assert!(out.stats.redispatched_trajectories > 0, "{:?}", out.stats);

    let status = child.wait().expect("waiting for killed engine-host");
    assert_eq!(status.code(), Some(9), "crash_exit must exit with code 9: {status:?}");
    coord.shutdown();
    a_thread.join().unwrap();
}
