//! Chaos tests for the fault-tolerant engine pool: supervised engine
//! threads + coordinator recovery, driven by the deterministic
//! fault-injection harness (`testkit::faulty`).
//!
//! The golden oracle: a stage that loses an engine mid-flight (crash,
//! panic, or stall caught by the watchdog) must recover on the survivors
//! and produce the SAME final trajectory set as a fault-free run — same
//! per-request token streams, modulo engine assignment. That holds
//! because mock token streams are scripted purely by (prompt,
//! params_epoch) and re-dispatch resumes from the coordinator-side
//! trajectory (the same replay path a buffered partial takes), so which
//! engine executes a request never changes its tokens.

use copris::config::{Config, RolloutMode};
use copris::coordinator::{Coordinator, RolloutOutput};
use copris::engine::{EnginePool, MockBackend};
use copris::tasks::Dataset;
use copris::testkit::faulty::{FaultKind, FaultOp, FaultPlan, FaultyBackend};
use copris::{prop_assert, prop_assert_eq};

const MAX_SEQ: usize = 96;

fn chaos_cfg(mode: RolloutMode) -> Config {
    let mut cfg = Config::new("mock");
    cfg.rollout.mode = mode;
    cfg.rollout.batch_prompts = 3;
    cfg.rollout.group_size = 2;
    cfg.rollout.concurrency = 4;
    cfg.rollout.temperature = 0.0; // greedy → streams scripted, no RNG
    cfg.engine.engines = 2;
    cfg.engine.retry_backoff_ms = 0;
    cfg.train.seed = 5;
    cfg
}

/// Pool where engine `target` runs the fault script and every other
/// engine is clean (all wrapped in `FaultyBackend` so the backend type —
/// and thus the fault-free baseline — is identical).
fn spawn_faulty(
    cfg: &Config,
    slots: usize,
    min_len: usize,
    spread: usize,
    target: usize,
    plans: Vec<FaultPlan>,
) -> EnginePool {
    EnginePool::spawn_supervised(
        cfg.engine.engines,
        slots,
        cfg.engine.engine_opts(),
        cfg.engine.supervisor_opts(),
        cfg.train.seed,
        move |id| {
            let plans = if id == target { plans.clone() } else { Vec::new() };
            Box::new(move || {
                let mut b = MockBackend::new(slots, MAX_SEQ);
                b.min_len = min_len;
                b.spread = spread;
                Ok(FaultyBackend::new(b, plans))
            })
        },
    )
    .unwrap()
}

/// Canonical stage fingerprint, invariant to completion order, trajectory
/// ids, and engine assignment: groups sorted by task prompt; per group
/// the sorted multiset of (token stream, behaviour-logprob bits).
type Fingerprint = Vec<(String, usize, Vec<(Vec<i32>, Vec<u32>)>)>;

fn fingerprint(out: &RolloutOutput) -> Fingerprint {
    let mut groups: Vec<_> = out
        .groups
        .iter()
        .map(|g| {
            let mut streams: Vec<(Vec<i32>, Vec<u32>)> = g
                .done
                .iter()
                .map(|t| {
                    (
                        t.tokens.clone(),
                        t.behavior_logprobs().iter().map(|l| l.to_bits()).collect(),
                    )
                })
                .collect();
            streams.sort();
            (g.task.prompt.clone(), g.target, streams)
        })
        .collect();
    groups.sort();
    groups
}

fn fault_free_fingerprint(cfg: &Config, slots: usize, min_len: usize, spread: usize) -> Fingerprint {
    let pool = spawn_faulty(cfg, slots, min_len, spread, 1, vec![]);
    let mut base = Coordinator::new(pool, cfg.clone(), MAX_SEQ);
    let mut ds = Dataset::train(cfg.train.seed);
    let out = base.rollout_stage(&mut ds).unwrap();
    assert_eq!(out.stats.engine_failures, 0);
    assert_eq!(out.stats.redispatched_trajectories, 0);
    let fp = fingerprint(&out);
    base.shutdown();
    fp
}

/// THE chaos acceptance check: engine 1 dies on its 2nd decode mid-stage;
/// the stage completes on the survivor with the exact fault-free
/// trajectory set, and the failure/re-dispatch stats record the event.
#[test]
fn crashed_engine_mid_stage_same_final_trajectories() {
    let cfg = chaos_cfg(RolloutMode::Sync);
    let want = fault_free_fingerprint(&cfg, 2, 6, 8);

    let plans = vec![FaultPlan { op: FaultOp::Decode, at_call: 2, kind: FaultKind::Fatal }];
    let mut coord =
        Coordinator::new(spawn_faulty(&cfg, 2, 6, 8, 1, plans), cfg.clone(), MAX_SEQ);
    let mut ds = Dataset::train(cfg.train.seed);
    let out = coord.rollout_stage(&mut ds).unwrap();
    assert_eq!(fingerprint(&out), want, "recovered stage diverged from fault-free run");
    assert_eq!(out.stats.engine_failures, 1, "{:?}", out.stats);
    assert!(out.stats.redispatched_trajectories > 0, "{:?}", out.stats);
    coord.shutdown();
}

/// Same oracle for a panicking backend (the `catch_unwind` supervisor
/// path): a panic mid-decode is one engine failure, not a lost stage.
#[test]
fn panicking_engine_mid_stage_same_final_trajectories() {
    let cfg = chaos_cfg(RolloutMode::Sync);
    let want = fault_free_fingerprint(&cfg, 2, 6, 8);

    let plans = vec![FaultPlan { op: FaultOp::Decode, at_call: 2, kind: FaultKind::Panic }];
    let mut coord =
        Coordinator::new(spawn_faulty(&cfg, 2, 6, 8, 1, plans), cfg.clone(), MAX_SEQ);
    let mut ds = Dataset::train(cfg.train.seed);
    let out = coord.rollout_stage(&mut ds).unwrap();
    assert_eq!(fingerprint(&out), want, "recovered stage diverged from fault-free run");
    assert_eq!(out.stats.engine_failures, 1, "{:?}", out.stats);
    assert!(out.stats.redispatched_trajectories > 0, "{:?}", out.stats);
    coord.shutdown();
}

/// Watchdog oracle: an engine that silently stops producing events (no
/// crash, no event) is declared dead after `engine.stall_timeout_ms` and
/// its work completes on the survivor — same fault-free trajectory set.
/// The stalled engine later wakes up and delivers its backlog; the
/// coordinator must discard those late events, not double-count them.
#[test]
fn stalled_engine_watchdog_same_final_trajectories() {
    let mut cfg = chaos_cfg(RolloutMode::Sync);
    cfg.engine.stall_timeout_ms = 300;
    let want = fault_free_fingerprint(&cfg, 2, 6, 8);

    let plans =
        vec![FaultPlan { op: FaultOp::Decode, at_call: 2, kind: FaultKind::Stall { ms: 1500 } }];
    let mut coord =
        Coordinator::new(spawn_faulty(&cfg, 2, 6, 8, 1, plans), cfg.clone(), MAX_SEQ);
    let mut ds = Dataset::train(cfg.train.seed);
    let out = coord.rollout_stage(&mut ds).unwrap();
    assert_eq!(fingerprint(&out), want, "watchdog recovery diverged from fault-free run");
    assert_eq!(out.stats.engine_failures, 1, "{:?}", out.stats);
    assert!(out.stats.redispatched_trajectories > 0, "{:?}", out.stats);
    coord.shutdown();
}

/// Degraded mode: losing EVERY engine is a structured error from
/// `rollout_stage` — never a hang, never a panic.
#[test]
fn all_engines_lost_is_a_structured_error() {
    let mut cfg = chaos_cfg(RolloutMode::Sync);
    cfg.engine.engines = 1;
    let plans = vec![FaultPlan { op: FaultOp::Decode, at_call: 2, kind: FaultKind::Fatal }];
    let mut coord =
        Coordinator::new(spawn_faulty(&cfg, 2, 6, 8, 0, plans), cfg.clone(), MAX_SEQ);
    let mut ds = Dataset::train(cfg.train.seed);
    let err = coord.rollout_stage(&mut ds).unwrap_err();
    assert!(format!("{err:#}").contains("degraded"), "{err:#}");
    coord.shutdown();
}

/// Transient errors are retried in place within the supervisor budget:
/// no engine failure, no re-dispatch, bit-identical streams, and the
/// retry count surfaces in the stage stats.
#[test]
fn transient_faults_recover_in_place_bit_exact() {
    let cfg = chaos_cfg(RolloutMode::Sync); // max_retries 3, backoff 0
    let want = fault_free_fingerprint(&cfg, 2, 6, 8);

    let plans = vec![FaultPlan {
        op: FaultOp::Decode,
        at_call: 2,
        kind: FaultKind::Transient { times: 2 },
    }];
    let mut coord =
        Coordinator::new(spawn_faulty(&cfg, 2, 6, 8, 1, plans), cfg.clone(), MAX_SEQ);
    let mut ds = Dataset::train(cfg.train.seed);
    let out = coord.rollout_stage(&mut ds).unwrap();
    assert_eq!(fingerprint(&out), want, "transient retry changed the streams");
    assert_eq!(out.stats.engine_failures, 0, "{:?}", out.stats);
    assert_eq!(out.stats.redispatched_trajectories, 0, "{:?}", out.stats);
    assert!(out.stats.retries >= 2, "{:?}", out.stats);
    coord.shutdown();
}

/// `retain_slot` failures at flush must be counted (`retain_errors`), not
/// swallowed — and must NOT kill the engine: the partial is flushed
/// plainly and the stage completes.
#[test]
fn retain_slot_errors_are_counted_not_fatal() {
    let mut cfg = chaos_cfg(RolloutMode::Copris);
    cfg.rollout.batch_prompts = 2;
    cfg.rollout.concurrency = 8;
    cfg.rollout.retain_kv = true;
    cfg.engine.engines = 1;
    cfg.train.seed = 7;
    let plans = vec![FaultPlan { op: FaultOp::RetainSlot, at_call: 1, kind: FaultKind::Fatal }];
    // Long scripts → busy slots at early termination → retain attempts.
    let mut coord =
        Coordinator::new(spawn_faulty(&cfg, 4, 20, 30, 0, plans), cfg.clone(), MAX_SEQ);
    let mut ds = Dataset::train(cfg.train.seed);
    let out = coord.rollout_stage(&mut ds).unwrap();
    assert_eq!(out.stats.engine_failures, 0, "{:?}", out.stats);
    assert!(out.stats.retain_errors > 0, "retain failure not counted: {:?}", out.stats);
    coord.shutdown();
}

/// Property: for a fault (fatal / panic / transient) injected at a swept
/// call index of a swept op, in sync and CoPRIS modes (with and without
/// KV retention), no trajectory is ever lost or duplicated — sync
/// delivers exactly the dispatched id set; CoPRIS harvests complete
/// groups with every done id unique across stages.
#[test]
fn fault_sweep_no_trajectory_lost_or_duplicated() {
    #[derive(Debug)]
    struct Case {
        mode: u64,
        op: FaultOp,
        kind: u64,
        at_call: usize,
    }
    copris::testkit::prop_check(
        "fault-sweep",
        10,
        |rng| Case {
            mode: rng.below(3),
            op: if rng.below(2) == 0 { FaultOp::Decode } else { FaultOp::Prefill },
            kind: rng.below(3),
            at_call: 1 + rng.below(10) as usize,
        },
        |c| {
            let mut cfg = chaos_cfg(if c.mode == 0 {
                RolloutMode::Sync
            } else {
                RolloutMode::Copris
            });
            cfg.rollout.retain_kv = c.mode == 2;
            let kind = match c.kind {
                0 => FaultKind::Fatal,
                1 => FaultKind::Panic,
                _ => FaultKind::Transient { times: 2 },
            };
            let plans = vec![FaultPlan { op: c.op, at_call: c.at_call, kind }];
            let mut coord =
                Coordinator::new(spawn_faulty(&cfg, 2, 4, 6, 1, plans), cfg.clone(), MAX_SEQ);
            let mut ds = Dataset::train(cfg.train.seed);
            let stages = if c.mode == 0 { 1 } else { 2 };
            let mut seen_ids: Vec<u64> = Vec::new();
            for stage in 0..stages {
                let out = coord
                    .rollout_stage(&mut ds)
                    .map_err(|e| format!("stage {stage} failed: {e:#}"))?;
                prop_assert_eq!(out.groups.len(), cfg.rollout.batch_prompts);
                for g in &out.groups {
                    prop_assert!(
                        g.done.len() >= cfg.rollout.group_size,
                        "incomplete group harvested: {} < {}",
                        g.done.len(),
                        cfg.rollout.group_size
                    );
                    for t in &g.done {
                        prop_assert!(t.complete && t.invariant_ok(), "bad trajectory {}", t.id);
                        seen_ids.push(t.id);
                    }
                }
            }
            let n = seen_ids.len();
            seen_ids.sort_unstable();
            seen_ids.dedup();
            prop_assert_eq!(seen_ids.len(), n); // no id delivered twice
            if c.mode == 0 {
                // Sync: exactly the B·G dispatched ids, none lost.
                let want: Vec<u64> = (0..(cfg.rollout.batch_prompts
                    * cfg.rollout.group_size) as u64)
                    .collect();
                prop_assert_eq!(seen_ids, want);
            }
            coord.shutdown();
            Ok(())
        },
    );
}
