//! Golden equivalence for the KV-retention resume path.
//!
//! The contract: whether a buffered partial resumes via retained KV
//! (affinity hit, zero replay) or via chunked/per-token replay, the
//! harvested trajectories are BIT-IDENTICAL — same tokens, same behaviour
//! log-prob bits. Determinism setup mirrors `rollout_golden.rs`: greedy
//! sampling (no RNG), 1 engine × 1 slot (completion order == dispatch
//! order), positional mock scripts (a partial cut at any timing-dependent
//! position resumes to the same final stream). See that file's header for
//! why multi-slot partial-mode arms must NOT be added to bit-identical
//! reference comparisons.
//!
//! Covered here:
//! - retained resume vs the frozen replay-only `ReferenceCoordinator`
//!   (bit-identical, with the fast path PROVEN taken: hits > 0, zero
//!   replayed tokens in the live arm);
//! - weight-sync invalidation: between stages (bit-identical to the
//!   reference, hits drop to zero) AND with the stage driver active — the
//!   pipelined "mid-flight" regime, including the abort/leftover-restore
//!   interaction (invariants only there: pre-sync admissions are
//!   legitimately timing-dependent);
//! - `rollout.retain_kv_across_sync`: stale-KV continuation stays on the
//!   fast path across a sync and keeps every trajectory invariant intact;
//! - eviction pressure (tight KV budget, retained-on vs retained-off live
//!   drivers) degrades gracefully to replay with identical outputs;
//! - paged-KV prompt-prefix sharing (`engine.prefix_sharing`, default on)
//!   is accounting-only: token+logprob streams are bit-identical to the
//!   sharing-off baseline across sync, copris, and retained-resume modes,
//!   with the sharing PROVEN active (`prefix_tokens_shared > 0`) in the
//!   live arm.

use std::sync::Arc;
use std::time::Duration;

use copris::config::{Config, RolloutMode};
use copris::coordinator::{Coordinator, ReferenceCoordinator, RolloutOutput};
use copris::engine::{EnginePool, MockBackend};
use copris::tasks::Dataset;

const MAX_SEQ: usize = 96;

fn spawn_pool(
    engines: usize,
    slots: usize,
    kv_budget: usize,
    seed: u64,
    min_len: usize,
    spread: usize,
    delay_us: u64,
) -> EnginePool {
    EnginePool::spawn(engines, slots, kv_budget, seed, move |_id| {
        Box::new(move || {
            let mut b = MockBackend::new(slots, MAX_SEQ);
            b.min_len = min_len;
            b.spread = spread;
            if delay_us > 0 {
                b.decode_delay = Some(Duration::from_micros(delay_us));
            }
            Ok(b)
        })
    })
    .unwrap()
}

/// Greedy single-file CoPRIS config with over-generation, so every stage
/// early-terminates with a partial in flight (which the live driver
/// retains and the next stage resumes).
fn retained_cfg() -> Config {
    let mut cfg = Config::new("mock");
    cfg.rollout.mode = RolloutMode::Copris;
    cfg.rollout.batch_prompts = 3;
    cfg.rollout.group_size = 2;
    cfg.rollout.concurrency = 4; // > B·G needed per stage → always in flight
    cfg.rollout.temperature = 0.0; // greedy → streams scripted, no RNG
    cfg.engine.engines = 1;
    cfg.train.seed = 5;
    cfg
}

/// Canonical stage fingerprint, invariant to completion order and
/// trajectory ids (same as rollout_golden.rs).
type Fingerprint = Vec<(String, usize, Vec<(Vec<i32>, Vec<u32>)>)>;

fn fingerprint(out: &RolloutOutput) -> Fingerprint {
    let mut groups: Vec<_> = out
        .groups
        .iter()
        .map(|g| {
            let mut streams: Vec<(Vec<i32>, Vec<u32>)> = g
                .done
                .iter()
                .map(|t| {
                    (
                        t.tokens.clone(),
                        t.behavior_logprobs().iter().map(|l| l.to_bits()).collect(),
                    )
                })
                .collect();
            streams.sort();
            (g.task.prompt.clone(), g.target, streams)
        })
        .collect();
    groups.sort();
    groups
}

/// THE acceptance check: retained-KV resume (live driver, retention on by
/// default) is bit-identical to the frozen replay-only reference across
/// multiple stages — and the fast path really ran: the live arm resumed
/// every buffered partial from retained KV (zero replayed tokens), while
/// the reference paid the replay cost for the same resumes.
#[test]
fn retained_resume_matches_replay_reference_bitwise() {
    let cfg = retained_cfg();
    assert!(cfg.rollout.retain_kv, "retention must be the default");
    let mut live = Coordinator::new(
        spawn_pool(1, 1, 0, cfg.train.seed, 4, 6, 200),
        cfg.clone(),
        MAX_SEQ,
    );
    let mut reference = ReferenceCoordinator::new(
        spawn_pool(1, 1, 0, cfg.train.seed, 4, 6, 200),
        cfg.clone(),
        MAX_SEQ,
    );
    let mut ds_live = Dataset::train(cfg.train.seed);
    let mut ds_ref = Dataset::train(cfg.train.seed);
    let mut hits = 0usize;
    let mut saved = 0u64;
    let mut live_replayed = 0u64;
    let mut ref_replayed = 0u64;
    for stage in 0..3 {
        let a = live.rollout_stage(&mut ds_live).unwrap();
        let b = reference.rollout_stage(&mut ds_ref).unwrap();
        assert_eq!(
            fingerprint(&a),
            fingerprint(&b),
            "retained resume diverged from replay reference at stage {stage}"
        );
        hits += a.stats.retained_hits;
        saved += a.stats.replay_tokens_saved;
        live_replayed += a.stats.replayed_tokens;
        ref_replayed += b.stats.replayed_tokens;
        assert_eq!(a.stats.retained_misses, 0, "no evictions/syncs → no misses");
    }
    // The fast path must actually have been exercised: stage 1 retains its
    // flushed slot, stage 2 pops that partial first (oldest version) and
    // the hint admits it straight into the retained slot.
    assert!(hits > 0, "no retained-KV hits across 3 stages");
    assert!(saved > 0, "hits with zero tokens saved");
    assert_eq!(
        live_replayed, 0,
        "retained arm replayed tokens despite affinity hits everywhere"
    );
    assert!(
        ref_replayed > 0,
        "reference arm should have paid replay for the same resumes"
    );
    live.shutdown();
    reference.shutdown();
}

/// Mid-flight weight sync: retention must be invalidated (default
/// `retain_kv_across_sync = false`), the resume falls back to replay under
/// the new params, and outputs stay bit-identical to the replay-only
/// reference performing the same sync.
#[test]
fn weight_sync_invalidates_retention_bitwise() {
    let cfg = retained_cfg();
    let mut live = Coordinator::new(
        spawn_pool(1, 1, 0, cfg.train.seed, 4, 6, 200),
        cfg.clone(),
        MAX_SEQ,
    );
    let mut reference = ReferenceCoordinator::new(
        spawn_pool(1, 1, 0, cfg.train.seed, 4, 6, 200),
        cfg.clone(),
        MAX_SEQ,
    );
    let mut ds_live = Dataset::train(cfg.train.seed);
    let mut ds_ref = Dataset::train(cfg.train.seed);

    let a1 = live.rollout_stage(&mut ds_live).unwrap();
    let b1 = reference.rollout_stage(&mut ds_ref).unwrap();
    assert_eq!(fingerprint(&a1), fingerprint(&b1), "stage 1");
    assert!(live.buffered() > 0, "over-generation must leave partials");
    assert!(live.retained_partials() > 0, "stage end must retain the partial");

    // The sync drops engine-side retained KV and the coordinator's
    // affinity map alike.
    let params = Arc::new(vec![1.5f32]);
    live.sync_weights(1, params.clone());
    reference.sync_weights(1, params);
    assert_eq!(live.retained_partials(), 0, "sync must clear the affinity map");

    let a2 = live.rollout_stage(&mut ds_live).unwrap();
    let b2 = reference.rollout_stage(&mut ds_ref).unwrap();
    assert_eq!(
        fingerprint(&a2),
        fingerprint(&b2),
        "post-sync resume diverged from replay reference"
    );
    assert_eq!(a2.stats.retained_hits, 0, "invalidated retention produced hits");
    assert_eq!(a2.stats.replay_tokens_saved, 0);
    assert!(
        a2.stats.replayed_tokens > 0,
        "post-sync resume must pay replay: {:?}",
        a2.stats
    );
    live.shutdown();
    reference.shutdown();
}

/// MID-FLIGHT invalidation: a sync while the stage driver is ACTIVE (the
/// pipelined regime — `sync_weights` lands between `begin_stage` and the
/// stage's completion) must clear the affinity map immediately and must
/// not be resurrected by the drain's leftover restore (which is guarded on
/// the dispatch-time policy version); the stage still delivers exactly B
/// invariant-correct groups either way. Timing-dependent quantities (how
/// many pre-sync hinted dispatches the engine admitted before SetParams
/// arrived — those are legitimate FIFO-ordered hits) are deliberately not
/// asserted.
#[test]
fn midflight_sync_invalidates_under_active_stage() {
    let cfg = retained_cfg();
    let mut coord = Coordinator::new(
        spawn_pool(1, 1, 0, cfg.train.seed, 8, 8, 400),
        cfg.clone(),
        MAX_SEQ,
    );
    let mut ds = Dataset::train(cfg.train.seed);
    let _ = coord.rollout_stage(&mut ds).unwrap(); // leaves a retained partial

    let check = |out: &RolloutOutput| {
        assert_eq!(out.groups.len(), 3);
        for grp in &out.groups {
            for t in &grp.done {
                assert!(t.complete && t.invariant_ok());
                let mut prev = t.born_version;
                for s in &t.segments {
                    assert!(s.policy_version >= prev, "non-decreasing versions");
                    prev = s.policy_version;
                }
            }
        }
    };

    // Sync with the driver active: the hinted resume is already dispatched.
    coord.begin_stage(&mut ds).unwrap();
    coord.sync_weights(1, Arc::new(vec![1.5f32]));
    assert_eq!(
        coord.retained_partials(),
        0,
        "mid-flight sync must clear the affinity map"
    );
    check(&coord.run_stage_to_completion(&mut ds).unwrap());

    // Abort path: begin → mid-flight sync → abort. Depending on timing the
    // in-flight hinted Assign was either unstarted (the version-guarded
    // leftover restore must NOT resurrect its invalidated hint) or already
    // admitted and re-flushed under the new version (legitimate fresh
    // retention). Both outcomes must leave a coordinator that resumes
    // every partial into a correct next stage.
    coord.begin_stage(&mut ds).unwrap();
    coord.sync_weights(2, Arc::new(vec![2.5f32]));
    coord.abort_stage().unwrap();
    assert!(
        coord.retained_partials() <= coord.buffered(),
        "affinity entries without a buffered partial"
    );
    check(&coord.rollout_stage(&mut ds).unwrap());
    coord.shutdown();
}

/// `retain_kv_across_sync = true`: the resume stays on the retained-KV
/// fast path ACROSS the sync (continuing from state computed under the old
/// params — the deliberate off-policy trade). Outputs are not compared to
/// the replay reference (they differ by design: replay re-prefills under
/// the NEW params); instead every structural invariant is checked and the
/// fast path is proven taken.
#[test]
fn retain_across_sync_continues_from_stale_kv() {
    let mut cfg = retained_cfg();
    cfg.rollout.retain_kv_across_sync = true;
    let mut coord = Coordinator::new(
        spawn_pool(1, 1, 0, cfg.train.seed, 4, 6, 200),
        cfg.clone(),
        MAX_SEQ,
    );
    let mut ds = Dataset::train(cfg.train.seed);
    let _ = coord.rollout_stage(&mut ds).unwrap();
    if coord.retained_partials() == 0 {
        // Vanishingly unlikely with over-generation; not an error.
        coord.shutdown();
        return;
    }
    coord.sync_weights(1, Arc::new(vec![1.5f32]));
    assert!(
        coord.retained_partials() > 0,
        "across-sync retention must survive the sync"
    );
    let out2 = coord.rollout_stage(&mut ds).unwrap();
    assert!(
        out2.stats.retained_hits > 0,
        "across-sync resume should hit retained KV: {:?}",
        out2.stats
    );
    for grp in &out2.groups {
        for t in &grp.done {
            assert!(t.complete && t.invariant_ok());
            assert_eq!(t.behavior_logprobs().len(), t.tokens.len(), "Eq. 6 concat");
            let mut prev = t.born_version;
            for s in &t.segments {
                assert!(s.policy_version >= prev, "non-decreasing segment versions");
                prev = s.policy_version;
            }
        }
    }
    coord.shutdown();
}

/// Paged-KV acceptance: with `engine.prefix_sharing` ON (the default), a
/// group's samples hold one refcounted copy of their prompt-prefix blocks
/// — and the harvested token + behaviour-logprob streams are BIT-IDENTICAL
/// to a sharing-off driver across:
/// - `sync` (all B·G upfront, groups share within the wave),
/// - `copris` with retention on across THREE stages — so stage 2+ resumes
///   run the retained-KV fast path and the replay path under sharing.
/// The sharing must actually happen in the on-arm (`prefix_tokens_shared`
/// accumulates; the off-arm stays at zero) — this is the ISSUE's
/// acceptance criterion at coordinator level (the exact G-samples/1-copy
/// block count is pinned by the engine unit test
/// `group_prefix_blocks_are_shared_once`).
#[test]
fn prefix_sharing_is_bit_identical_across_modes() {
    for mode in [RolloutMode::Sync, RolloutMode::Copris] {
        let mut cfg_on = retained_cfg();
        cfg_on.rollout.mode = mode;
        assert!(cfg_on.engine.prefix_sharing, "prefix sharing must default on");
        assert!(cfg_on.rollout.retain_kv, "retention stays on: resumes take the fast path");
        let mut cfg_off = cfg_on.clone();
        cfg_off.engine.prefix_sharing = false;

        let mut on = Coordinator::new(
            spawn_pool(1, 1, 0, cfg_on.train.seed, 4, 6, 200),
            cfg_on.clone(),
            MAX_SEQ,
        );
        let mut off = Coordinator::new(
            spawn_pool(1, 1, 0, cfg_on.train.seed, 4, 6, 200),
            cfg_off,
            MAX_SEQ,
        );
        let mut ds_on = Dataset::train(cfg_on.train.seed);
        let mut ds_off = Dataset::train(cfg_on.train.seed);
        let mut shared_on = 0u64;
        let mut shared_off = 0u64;
        let mut hits_on = 0usize;
        for stage in 0..3 {
            let a = on.rollout_stage(&mut ds_on).unwrap();
            let b = off.rollout_stage(&mut ds_off).unwrap();
            assert_eq!(
                fingerprint(&a),
                fingerprint(&b),
                "prefix sharing changed a stream: mode {mode:?} stage {stage}"
            );
            shared_on += a.stats.prefix_tokens_shared;
            shared_off += b.stats.prefix_tokens_shared;
            hits_on += a.stats.retained_hits;
        }
        assert!(
            shared_on > 0,
            "sharing-on arm never shared a prefix ({mode:?})"
        );
        assert_eq!(shared_off, 0, "sharing-off arm must not share");
        if mode == RolloutMode::Copris {
            // Over-generation leaves partials each stage; with retention
            // on, stage 2+ resumes exercise the retained fast path UNDER
            // prefix sharing.
            assert!(hits_on > 0, "no retained-resume under sharing");
        }
        on.shutdown();
        off.shutdown();
    }
}

/// Eviction pressure: an eval between stages floods the single slot with
/// fresh eval work, which DETERMINISTICALLY evicts the retained slot
/// (queued work never starves behind parked KV), the engine's
/// `RetainedDropped` clears the coordinator's affinity map mid-eval, and
/// the post-eval resume falls back to replay — bit-identical to a live
/// driver that never retained. (Budget-pressure eviction ordering —
/// retained before live, LIFO — is pinned deterministically by the engine
/// unit tests; the frozen reference is not used here because its
/// drain-leftover parking order is HashMap-dependent under multi-partial
/// drains — see rollout_golden.rs's header.)
#[test]
fn eviction_pressure_degrades_to_replay_bitwise() {
    let cfg_on = retained_cfg();
    let mut cfg_off = cfg_on.clone();
    cfg_off.rollout.retain_kv = false;

    let mut on = Coordinator::new(
        spawn_pool(1, 1, 0, cfg_on.train.seed, 4, 6, 200),
        cfg_on.clone(),
        MAX_SEQ,
    );
    let mut off = Coordinator::new(
        spawn_pool(1, 1, 0, cfg_on.train.seed, 4, 6, 200),
        cfg_off,
        MAX_SEQ,
    );
    let mut ds_on = Dataset::train(cfg_on.train.seed);
    let mut ds_off = Dataset::train(cfg_on.train.seed);

    let a1 = on.rollout_stage(&mut ds_on).unwrap();
    let b1 = off.rollout_stage(&mut ds_off).unwrap();
    assert_eq!(fingerprint(&a1), fingerprint(&b1), "stage 1");
    assert!(on.retained_partials() > 0, "stage end must retain the partial");

    // Eval work floods the slot → the retained slot is evicted to admit
    // it; the drop event clears the affinity entry during the eval pump.
    let suite = &copris::tasks::eval_suites()[0];
    let tasks = suite.tasks(2, 9);
    let sampling = copris::engine::SamplingParams::greedy();
    let ga = on.run_fixed_sync(&tasks, 2, sampling).unwrap();
    let gb = off.run_fixed_sync(&tasks, 2, sampling).unwrap();
    assert_eq!(ga.len(), gb.len());
    assert_eq!(
        on.retained_partials(),
        0,
        "eval admission pressure must evict retained KV and clear affinity"
    );
    assert_eq!(on.buffered(), off.buffered(), "eval must not touch the buffer");

    // Post-eval resume: no hint survives → plain replay, identical output.
    let a2 = on.rollout_stage(&mut ds_on).unwrap();
    let b2 = off.rollout_stage(&mut ds_off).unwrap();
    assert_eq!(
        fingerprint(&a2),
        fingerprint(&b2),
        "post-eviction resume diverged from the replay-only driver"
    );
    assert_eq!(a2.stats.retained_hits, 0, "evicted retention produced hits");
    assert!(
        a2.stats.replayed_tokens > 0,
        "post-eviction resume must pay replay: {:?}",
        a2.stats
    );
    for grp in &a2.groups {
        for t in &grp.done {
            assert!(t.complete && t.invariant_ok());
        }
    }
    on.shutdown();
    off.shutdown();
}
