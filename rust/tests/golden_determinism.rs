//! Golden-determinism guard for the zero-allocation decode-path rewrite:
//! the engine (scratch sampler, incremental bookkeeping, `decode_into`
//! buffer reuse) must reproduce — token for token, logprob bit for bit —
//! an independent simulation driven by the straightforward allocating
//! reference sampler (`sampler::reference`) over the same `MockBackend`
//! script and the same `Rng` stream.

use copris::engine::sampler::reference::sample_token_ref;
use copris::engine::{Engine, EngineEvent, MockBackend, SamplingParams, WorkItem, WorkResult};
use copris::tokenizer;
use copris::util::Rng;

const MAX_SEQ: usize = 96;

fn run_engine_single_slot(
    prompts: &[Vec<i32>],
    sampling: SamplingParams,
    seed: u64,
) -> Vec<WorkResult> {
    let be = MockBackend::new(1, MAX_SEQ);
    let mut eng = Engine::new(0, be, 0, seed);
    for (i, p) in prompts.iter().enumerate() {
        eng.submit(WorkItem {
            request_id: i as u64,
            prompt: p.clone().into(),
            resume: vec![],
            max_total: MAX_SEQ,
            sampling,
            retain: None,
            prefix: None,
        })
        .unwrap();
    }
    let mut out = Vec::new();
    let mut ev = Vec::new();
    for _ in 0..2000 {
        if !eng.has_work() {
            break;
        }
        eng.step(&mut ev).unwrap();
        for e in ev.drain(..) {
            if let EngineEvent::Done { result, .. } = e {
                out.push(result);
            }
        }
    }
    assert!(!eng.has_work(), "engine did not drain");
    out
}

/// Independent reimplementation of the single-slot generation loop: raw
/// `MockBackend` calls + the allocating reference sampler, consuming the
/// SAME rng stream the engine consumes (engine id 0 → `Rng::new(seed)`).
fn simulate_single_slot(
    prompts: &[Vec<i32>],
    sampling: SamplingParams,
    seed: u64,
) -> Vec<(Vec<i32>, Vec<f32>)> {
    let mut be = MockBackend::new(1, MAX_SEQ);
    let mut rng = Rng::new(seed);
    let mut out = Vec::new();
    for prompt in prompts {
        let mut tokens = Vec::new();
        let mut logprobs = Vec::new();
        let mut logits = be.prefill(0, prompt).unwrap();
        loop {
            let (tok, lp) = sample_token_ref(&logits, &sampling, &mut rng);
            tokens.push(tok);
            logprobs.push(lp);
            if tok == tokenizer::EOS || prompt.len() + tokens.len() >= MAX_SEQ {
                break;
            }
            logits = be.decode(&[0], &[0]).unwrap();
        }
        out.push((tokens, logprobs));
    }
    out
}

fn assert_matches_simulation(sampling: SamplingParams, seed: u64) {
    let prompts: Vec<Vec<i32>> =
        vec![vec![1, 7, 7], vec![1, 4, 9, 5], vec![1, 12], vec![1, 6, 6, 6, 8]];
    let mut results = run_engine_single_slot(&prompts, sampling, seed);
    results.sort_by_key(|r| r.request_id);
    let sim = simulate_single_slot(&prompts, sampling, seed);
    assert_eq!(results.len(), sim.len());
    for (r, (want_toks, want_lps)) in results.iter().zip(&sim) {
        assert_eq!(&r.new_tokens, want_toks, "req {}: token sequence diverged", r.request_id);
        let got_bits: Vec<u32> = r.new_logprobs.iter().map(|x| x.to_bits()).collect();
        let want_bits: Vec<u32> = want_lps.iter().map(|x| x.to_bits()).collect();
        assert_eq!(got_bits, want_bits, "req {}: logprob bits diverged", r.request_id);
    }
}

#[test]
fn engine_matches_reference_simulation_default_params() {
    assert_matches_simulation(SamplingParams::default(), 42);
    assert_matches_simulation(SamplingParams::default(), 7);
}

#[test]
fn engine_matches_reference_simulation_filtered_params() {
    // Exercises the top-k partial-selection and top-p nucleus scratch
    // paths through full generations.
    let p = SamplingParams { temperature: 0.9, top_p: 0.92, top_k: 8 };
    assert_matches_simulation(p, 42);
    let p = SamplingParams { temperature: 1.1, top_p: 1.0, top_k: 4 };
    assert_matches_simulation(p, 3);
}

/// Multi-slot runs must be exactly reproducible across engine instances
/// (slot-order rng interleaving, incremental counters, buffer reuse).
#[test]
fn multi_slot_runs_are_bitwise_reproducible() {
    let run = || -> Vec<(u64, Vec<i32>, Vec<u32>)> {
        let be = MockBackend::new(4, MAX_SEQ);
        let mut eng = Engine::new(0, be, 60, 5); // kv budget → some preemption
        for i in 0..10u64 {
            eng.submit(WorkItem {
                request_id: i,
                prompt: vec![1, (i % 9) as i32 + 4, 9].into(),
                resume: vec![],
                max_total: MAX_SEQ,
                sampling: SamplingParams::default(),
                retain: None,
                prefix: None,
            })
            .unwrap();
        }
        let mut out = Vec::new();
        let mut ev = Vec::new();
        for _ in 0..3000 {
            if !eng.has_work() {
                break;
            }
            eng.step(&mut ev).unwrap();
            for e in ev.drain(..) {
                if let EngineEvent::Done { result, .. } = e {
                    let bits = result.new_logprobs.iter().map(|x| x.to_bits()).collect();
                    out.push((result.request_id, result.new_tokens, bits));
                }
            }
        }
        assert_eq!(eng.busy(), 0);
        assert_eq!(eng.kv_tokens(), 0);
        out
    };
    let a = run();
    let b = run();
    assert_eq!(a, b, "same seed must reproduce the exact event stream");
    assert!(!a.is_empty());
}
