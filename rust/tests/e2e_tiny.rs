//! End-to-end integration on the real `tiny` artifacts: SFT warmup → CoPRIS
//! rollout (XLA engines on threads) → GRPO update with cross-stage IS →
//! weight sync → eval. Small step counts — this is a plumbing test, not a
//! convergence run (EXPERIMENTS.md records the real runs).

use copris::config::{scaled_preset, RolloutMode};
use copris::engine::{
    Backend, Engine, EngineEvent, FinishReason, SamplingParams, WorkItem, WorkResult, XlaBackend,
};
use copris::exp::RlSession;
use copris::model::ModelRuntime;

fn have_artifacts() -> bool {
    let ok = std::path::Path::new("artifacts/tiny/manifest.json").exists();
    if !ok {
        eprintln!("skipping: artifacts/tiny missing (run `make artifacts`)");
    }
    ok
}

fn tiny_cfg(mode: RolloutMode) -> copris::config::Config {
    let mut cfg = scaled_preset("tiny");
    cfg.rollout.mode = mode;
    cfg.rollout.batch_prompts = 2;
    cfg.rollout.group_size = 2;
    cfg.rollout.concurrency = 6;
    cfg.engine.engines = 2;
    cfg.train.seed = 3;
    cfg.eval.prompts_per_suite = 2;
    cfg.eval.samples_per_prompt = 1;
    cfg
}

#[test]
fn full_pipeline_copris_with_is() {
    if !have_artifacts() {
        return;
    }
    let mut sess = RlSession::build(tiny_cfg(RolloutMode::Copris)).unwrap();

    // SFT warmup must produce finite losses (steps share the optimizer
    // counter with RL, matching a single train state).
    let mut losses = Vec::new();
    for _ in 0..3 {
        losses.push(sess.sft_warmup(2, 1).unwrap());
    }
    assert!(losses.iter().all(|l| l.is_finite()));
    let step_after_warmup = sess.trainer.step();

    // Three RL steps end-to-end.
    let summary = sess.train(3).unwrap();
    assert_eq!(summary.steps, 3);
    assert!(summary.wall > 0.0);
    assert!(summary.throughput > 0.0);
    assert_eq!(summary.reward_curve.len(), 3);
    assert!(summary.reward_curve.iter().all(|r| (0.0..=1.0).contains(r)));
    assert!(summary.entropy_curve.iter().all(|e| e.is_finite() && *e >= 0.0));
    assert_eq!(sess.trainer.step(), step_after_warmup + 3);

    // Eval runs over all five suites.
    let report = sess.evaluate(1).unwrap();
    assert_eq!(report.suites.len(), 5);
    for s in &report.suites {
        assert!((0.0..=1.0).contains(&s.pass_at_1), "{s:?}");
    }
    sess.shutdown();
}

#[test]
fn full_pipeline_sync_baseline() {
    if !have_artifacts() {
        return;
    }
    let mut sess = RlSession::build(tiny_cfg(RolloutMode::Sync)).unwrap();
    sess.sft_warmup(2, 1).unwrap();
    let summary = sess.train(2).unwrap();
    assert_eq!(summary.steps, 2);
    // Sync mode buffers nothing and replays nothing.
    assert_eq!(summary.replayed_tokens, 0);
    assert_eq!(sess.coord.buffered(), 0);
    sess.shutdown();
}

/// Single-threaded XLA engine over the tiny artifacts with deterministic
/// (seeded) init params — both arms of the retention test build identical
/// engines.
fn xla_engine() -> Engine<XlaBackend> {
    let mut rt = ModelRuntime::open("artifacts", "tiny").unwrap();
    let state = rt.init_state(3).unwrap();
    let params = rt.params_to_host(&state).unwrap();
    drop(rt);
    let be = XlaBackend::open("artifacts", "tiny", &params).unwrap();
    Engine::new(0, be, 0, 7)
}

fn drive_to_terminal(eng: &mut Engine<XlaBackend>, max_steps: usize) -> WorkResult {
    let mut ev = Vec::new();
    for _ in 0..max_steps {
        eng.step(&mut ev).unwrap();
        for e in ev.drain(..) {
            if let EngineEvent::Done { result, .. } = e {
                if result.reason.is_complete() {
                    return result;
                }
            }
        }
    }
    panic!("no terminal result within {max_steps} steps");
}

/// The real-backend half of the retention contract: `XlaBackend` claims
/// retention is free because the per-slot KV is device-resident and the
/// engine's parked-position discipline keeps it intact (a write-then-attend
/// kernel never exposes the dummy write at the pending feed position). The
/// mock-backed golden tests cannot verify that claim — this artifact-gated
/// test does: a greedy run stopped mid-way and resumed from retained KV
/// must reproduce the uninterrupted run's token stream exactly, with zero
/// replayed tokens.
#[test]
fn xla_retained_resume_matches_uninterrupted_stream() {
    if !have_artifacts() {
        return;
    }
    let prompt: Vec<i32> = vec![1, 5, 6];
    let sampling = SamplingParams::greedy();
    let item = |id: u64, prompt: &[i32], resume: Vec<i32>, retain: Option<u64>, cap: usize| {
        WorkItem {
            request_id: id,
            prompt: prompt.to_vec().into(),
            resume,
            max_total: cap,
            sampling,
            retain,
            prefix: None,
        }
    };

    // Oracle: the uninterrupted greedy run (identical init params).
    let mut control = xla_engine();
    let cap = control.backend().max_seq().min(prompt.len() + 24);
    control.submit(item(1, &prompt, vec![], None, cap)).unwrap();
    let want = drive_to_terminal(&mut control, 200);

    // Retained arm: stop after a few decode steps, resume from the slot.
    let mut eng = xla_engine();
    eng.submit(item(1, &prompt, vec![], None, cap)).unwrap();
    let mut ev = Vec::new();
    for _ in 0..4 {
        eng.step(&mut ev).unwrap();
    }
    ev.clear();
    eng.stop_generation(&mut ev, true);
    let partial = ev.iter().find_map(|e| match e {
        EngineEvent::Done { result, .. } if result.reason == FinishReason::Stopped => {
            Some(result.clone())
        }
        _ => None,
    });
    let Some(partial) = partial else {
        // The (random-init) model terminated within 4 steps — nothing to
        // retain this run; the mock-backed tests still pin the machinery.
        eprintln!("skipping: run completed before the stop landed");
        return;
    };
    let token = partial.retained.expect("caught-up XLA slot must retain");
    assert!(eng.kv_tokens() > 0, "retained KV must stay charged");

    // THE risky phase of the contract: run a full unrelated request while
    // the slot is parked. Every lockstep decode step stages the retained
    // slot at its pending feed position with a dummy token — a kernel that
    // attends that dummy write (or otherwise disturbs the parked lane)
    // corrupts the retained prefix, and the resume below catches it.
    if eng.backend().slots() >= 2 {
        let other: Vec<i32> = vec![1, 9, 4];
        let other_cap = eng.backend().max_seq().min(other.len() + 24);
        eng.submit(item(2, &other, vec![], None, other_cap)).unwrap();
        let _ = drive_to_terminal(&mut eng, 200);
        assert_eq!(eng.retained(), 1, "parked slot must survive other work");
    } else {
        eprintln!("single-slot artifact: parked-lane decode stress skipped");
    }

    eng.submit(item(1, &prompt, partial.new_tokens.clone(), Some(token), cap)).unwrap();
    let done = drive_to_terminal(&mut eng, 200);
    assert!(done.resumed_from_kv, "hinted resume must hit retained KV");
    assert_eq!(done.replayed, 0, "retained resume must replay nothing");

    let full: Vec<i32> =
        partial.new_tokens.iter().chain(done.new_tokens.iter()).copied().collect();
    assert_eq!(
        full, want.new_tokens,
        "retained-KV resume diverged from the uninterrupted XLA run — \
         the backend's write-then-attend retention contract is violated"
    );
    assert_eq!(done.reason, want.reason);
}

#[test]
fn full_pipeline_without_is_matches_shapes() {
    if !have_artifacts() {
        return;
    }
    let mut cfg = tiny_cfg(RolloutMode::Copris);
    cfg.rollout.importance_sampling = false; // w/o IS ablation path
    let mut sess = RlSession::build(cfg).unwrap();
    sess.sft_warmup(1, 1).unwrap();
    let summary = sess.train(2).unwrap();
    assert_eq!(summary.steps, 2);
    sess.shutdown();
}
