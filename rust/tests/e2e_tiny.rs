//! End-to-end integration on the real `tiny` artifacts: SFT warmup → CoPRIS
//! rollout (XLA engines on threads) → GRPO update with cross-stage IS →
//! weight sync → eval. Small step counts — this is a plumbing test, not a
//! convergence run (EXPERIMENTS.md records the real runs).

use copris::config::{scaled_preset, RolloutMode};
use copris::exp::RlSession;

fn have_artifacts() -> bool {
    let ok = std::path::Path::new("artifacts/tiny/manifest.json").exists();
    if !ok {
        eprintln!("skipping: artifacts/tiny missing (run `make artifacts`)");
    }
    ok
}

fn tiny_cfg(mode: RolloutMode) -> copris::config::Config {
    let mut cfg = scaled_preset("tiny");
    cfg.rollout.mode = mode;
    cfg.rollout.batch_prompts = 2;
    cfg.rollout.group_size = 2;
    cfg.rollout.concurrency = 6;
    cfg.engine.engines = 2;
    cfg.train.seed = 3;
    cfg.eval.prompts_per_suite = 2;
    cfg.eval.samples_per_prompt = 1;
    cfg
}

#[test]
fn full_pipeline_copris_with_is() {
    if !have_artifacts() {
        return;
    }
    let mut sess = RlSession::build(tiny_cfg(RolloutMode::Copris)).unwrap();

    // SFT warmup must produce finite losses (steps share the optimizer
    // counter with RL, matching a single train state).
    let mut losses = Vec::new();
    for _ in 0..3 {
        losses.push(sess.sft_warmup(2, 1).unwrap());
    }
    assert!(losses.iter().all(|l| l.is_finite()));
    let step_after_warmup = sess.trainer.step();

    // Three RL steps end-to-end.
    let summary = sess.train(3).unwrap();
    assert_eq!(summary.steps, 3);
    assert!(summary.wall > 0.0);
    assert!(summary.throughput > 0.0);
    assert_eq!(summary.reward_curve.len(), 3);
    assert!(summary.reward_curve.iter().all(|r| (0.0..=1.0).contains(r)));
    assert!(summary.entropy_curve.iter().all(|e| e.is_finite() && *e >= 0.0));
    assert_eq!(sess.trainer.step(), step_after_warmup + 3);

    // Eval runs over all five suites.
    let report = sess.evaluate(1).unwrap();
    assert_eq!(report.suites.len(), 5);
    for s in &report.suites {
        assert!((0.0..=1.0).contains(&s.pass_at_1), "{s:?}");
    }
    sess.shutdown();
}

#[test]
fn full_pipeline_sync_baseline() {
    if !have_artifacts() {
        return;
    }
    let mut sess = RlSession::build(tiny_cfg(RolloutMode::Sync)).unwrap();
    sess.sft_warmup(2, 1).unwrap();
    let summary = sess.train(2).unwrap();
    assert_eq!(summary.steps, 2);
    // Sync mode buffers nothing and replays nothing.
    assert_eq!(summary.replayed_tokens, 0);
    assert_eq!(sess.coord.buffered(), 0);
    sess.shutdown();
}

#[test]
fn full_pipeline_without_is_matches_shapes() {
    if !have_artifacts() {
        return;
    }
    let mut cfg = tiny_cfg(RolloutMode::Copris);
    cfg.rollout.importance_sampling = false; // w/o IS ablation path
    let mut sess = RlSession::build(cfg).unwrap();
    sess.sft_warmup(1, 1).unwrap();
    let summary = sess.train(2).unwrap();
    assert_eq!(summary.steps, 2);
    sess.shutdown();
}
