//! Coordinator integration + property tests over the MOCK backend: the
//! full CoPRIS dispatch machinery (concurrency control, early termination,
//! buffering, prioritized resumption, group bookkeeping) without PJRT.

use copris::config::{Config, RolloutMode};
use copris::coordinator::Coordinator;
use copris::engine::{EnginePool, MockBackend};
use copris::tasks::Dataset;
use copris::testkit::prop_check;
use copris::tokenizer::EOS;
use copris::util::Rng;

const SLOTS: usize = 4;
const MAX_SEQ: usize = 96;

/// `delay_us` slows the mock decode step; without it the engines outrun
/// the coordinator's control channel and finish everything before
/// StopGeneration lands (real engines take milliseconds per step).
fn mock_coordinator_with(
    cfg: Config,
    min_len: usize,
    spread: usize,
    delay_us: u64,
) -> Coordinator {
    let engines = cfg.engine.engines;
    let kv = cfg.engine.kv_cache_config();
    let pool = EnginePool::spawn_kv(engines, SLOTS, kv, cfg.train.seed, move |_id| {
        Box::new(move || {
            let mut b = MockBackend::new(SLOTS, MAX_SEQ);
            b.min_len = min_len;
            b.spread = spread;
            if delay_us > 0 {
                b.decode_delay = Some(std::time::Duration::from_micros(delay_us));
            }
            Ok(b)
        })
    })
    .unwrap();
    Coordinator::new(pool, cfg.clone(), MAX_SEQ)
}

fn mock_coordinator(cfg: Config, min_len: usize, spread: usize) -> Coordinator {
    mock_coordinator_with(cfg, min_len, spread, 0)
}

fn base_cfg(mode: RolloutMode, concurrency: usize, seed: u64) -> Config {
    let mut cfg = Config::new("mock");
    cfg.rollout.mode = mode;
    cfg.rollout.batch_prompts = 4;
    cfg.rollout.group_size = 4;
    cfg.rollout.concurrency = concurrency;
    cfg.engine.engines = 2;
    cfg.train.seed = seed;
    cfg
}

/// Check every trajectory of a rollout output for structural invariants.
fn check_groups(out: &copris::coordinator::RolloutOutput, b: usize, g: usize) -> Result<(), String> {
    if out.groups.len() != b {
        return Err(format!("expected {b} groups, got {}", out.groups.len()));
    }
    for grp in &out.groups {
        if grp.done.len() != g {
            return Err(format!("group {} has {} trajectories", grp.group_id, grp.done.len()));
        }
        for t in &grp.done {
            if !t.complete {
                return Err(format!("incomplete trajectory {} harvested", t.id));
            }
            if !t.invariant_ok() {
                return Err(format!("trajectory {} segment/token mismatch", t.id));
            }
            if t.is_empty() {
                return Err(format!("trajectory {} has no tokens", t.id));
            }
            // Terminal trajectories end with EOS or hit the length cap.
            let last = *t.tokens.last().unwrap();
            let total = t.prompt.len() + t.tokens.len();
            if last != EOS && total < MAX_SEQ {
                return Err(format!(
                    "trajectory {} ended without EOS at len {total}",
                    t.id
                ));
            }
        }
    }
    Ok(())
}

#[test]
fn sync_rollout_collects_exact_batch() {
    let cfg = base_cfg(RolloutMode::Sync, 0, 1);
    let mut coord = mock_coordinator(cfg, 2, 12);
    let mut ds = Dataset::train(1);
    let out = coord.rollout_stage(&mut ds).unwrap();
    check_groups(&out, 4, 4).unwrap();
    assert_eq!(out.stats.partials_buffered, 0, "sync never buffers partials");
    assert_eq!(coord.buffered(), 0);
    coord.shutdown();
}

#[test]
fn copris_rollout_terminates_early_and_buffers_partials() {
    let cfg = base_cfg(RolloutMode::Copris, 8, 2);
    // Long scripted lengths + slow decode → in-flight partials at early
    // termination (the paper: ~N'-1 partials remain).
    let mut coord = mock_coordinator_with(cfg, 20, 40, 500);
    let mut ds = Dataset::train(2);
    let out = coord.rollout_stage(&mut ds).unwrap();
    check_groups(&out, 4, 4).unwrap();
    // With N'=8 concurrent and only 16 needed, partials must be buffered
    // (the paper: N'-1 partials remain at early termination).
    assert!(
        out.stats.partials_buffered > 0 || coord.buffered() > 0,
        "expected buffered partials: {:?}",
        out.stats
    );
    coord.shutdown();
}

#[test]
fn copris_resumes_buffered_partials_next_stage() {
    let cfg = base_cfg(RolloutMode::Copris, 8, 3);
    let mut coord = mock_coordinator_with(cfg, 10, 30, 300);
    let mut ds = Dataset::train(3);
    let out1 = coord.rollout_stage(&mut ds).unwrap();
    let buffered = coord.buffered();
    if buffered == 0 {
        // Extremely unlikely with these script lengths, but not an error.
        coord.shutdown();
        return;
    }
    let out2 = coord.rollout_stage(&mut ds).unwrap();
    check_groups(&out2, 4, 4).unwrap();
    // Cross-stage trajectories exist in stage 2 only if the policy version
    // advanced; without sync_weights the version is unchanged, so segments
    // merge. Either way, resumption must be visible in the accounting:
    // as replayed tokens (replay path) or as replay tokens saved
    // (retained-KV affinity hits — on by default).
    assert!(out2.stats.resumed > 0, "buffer pops not counted: {:?}", out2.stats);
    assert!(
        out2.stats.replayed_tokens + out2.stats.replay_tokens_saved > 0,
        "resumption cost/saving invisible: {:?}",
        out2.stats
    );
    let _ = out1;
    coord.shutdown();
}

#[test]
fn cross_stage_segments_tagged_by_version() {
    let cfg = base_cfg(RolloutMode::Copris, 8, 4);
    let mut coord = mock_coordinator_with(cfg, 15, 30, 300);
    let mut ds = Dataset::train(4);
    let _ = coord.rollout_stage(&mut ds).unwrap();
    if coord.buffered() == 0 {
        coord.shutdown();
        return;
    }
    // Simulate a policy update between stages.
    coord.sync_weights(1, std::sync::Arc::new(vec![1.5f32]));
    let out2 = coord.rollout_stage(&mut ds).unwrap();
    let cross: Vec<_> = out2
        .groups
        .iter()
        .flat_map(|g| g.done.iter())
        .filter(|t| t.n_stages() > 1)
        .collect();
    for t in &cross {
        assert_eq!(t.segments[0].policy_version, 0);
        assert_eq!(t.segments.last().unwrap().policy_version, 1);
        assert!(t.invariant_ok());
        assert!(t.offpolicy_tokens(1) > 0);
        // Eq. 6: concat length equals token count.
        assert_eq!(t.behavior_logprobs().len(), t.tokens.len());
    }
    coord.shutdown();
}

#[test]
fn naive_partial_does_not_refill() {
    let cfg = base_cfg(RolloutMode::NaivePartial, 24, 5);
    let mut coord = mock_coordinator(cfg, 4, 10);
    let mut ds = Dataset::train(5);
    let out = coord.rollout_stage(&mut ds).unwrap();
    check_groups(&out, 4, 4).unwrap();
    // Initial wave is `concurrency` = 24 dispatches; queue drains without
    // refill, so peak in-flight never exceeds the wave size.
    assert!(out.stats.peak_inflight <= 24);
    coord.shutdown();
}

#[test]
fn eval_fixed_sync_returns_group_per_task() {
    let cfg = base_cfg(RolloutMode::Copris, 8, 6);
    let mut coord = mock_coordinator(cfg, 3, 6);
    let suite = &copris::tasks::eval_suites()[0];
    let tasks = suite.tasks(6, 7);
    let groups = coord
        .run_fixed_sync(&tasks, 3, copris::engine::SamplingParams::default())
        .unwrap();
    assert_eq!(groups.len(), 6);
    for (g, task) in groups.iter().zip(tasks.iter()) {
        assert_eq!(g.done.len(), 3);
        assert_eq!(g.task.prompt, task.prompt, "eval groups keep task order");
    }
    coord.shutdown();
}

/// Paged-KV prefix sharing end-to-end: with the default config (sharing
/// on), a copris stage shares group prompt prefixes (the stats prove it),
/// while a sharing-off twin of the same run shares nothing — and both
/// deliver the identical exact batch.
#[test]
fn prefix_sharing_shares_group_prompts_across_the_stack() {
    let cfg_on = base_cfg(RolloutMode::Copris, 8, 12);
    assert!(cfg_on.engine.prefix_sharing, "sharing must default on");
    let mut cfg_off = cfg_on.clone();
    cfg_off.engine.prefix_sharing = false;

    let mut on = mock_coordinator_with(cfg_on, 8, 12, 200);
    let mut off = mock_coordinator_with(cfg_off, 8, 12, 200);
    let mut ds_on = Dataset::train(12);
    let mut ds_off = Dataset::train(12);
    let a = on.rollout_stage(&mut ds_on).unwrap();
    let b = off.rollout_stage(&mut ds_off).unwrap();
    check_groups(&a, 4, 4).unwrap();
    check_groups(&b, 4, 4).unwrap();
    assert!(
        a.stats.prefix_tokens_shared > 0,
        "G=4 groups must share prompt prefixes: {:?}",
        a.stats
    );
    assert!(a.stats.kv_blocks_peak > 0, "block gauge missing: {:?}", a.stats);
    assert_eq!(b.stats.prefix_tokens_shared, 0, "sharing-off arm shared");
    on.shutdown();
    off.shutdown();
}

// ---------------------------------------------------------------------------
// property tests (hand-rolled prop framework; proptest unavailable offline)
// ---------------------------------------------------------------------------

#[test]
fn prop_all_modes_and_settings_yield_exact_complete_batches() {
    prop_check(
        "rollout-batch-exactness",
        12,
        |rng: &mut Rng| {
            let mode = match rng.below(3) {
                0 => RolloutMode::Sync,
                1 => RolloutMode::NaivePartial,
                _ => RolloutMode::Copris,
            };
            let concurrency = 2 + rng.below(14) as usize;
            let min_len = 2 + rng.below(12) as usize;
            let spread = 2 + rng.below(30) as usize;
            let seed = rng.next_u64() % 1000;
            (mode, concurrency, min_len, spread, seed)
        },
        |&(mode, concurrency, min_len, spread, seed)| {
            let mut cfg = base_cfg(mode, concurrency, seed);
            cfg.rollout.batch_prompts = 2 + (seed % 3) as usize;
            cfg.rollout.group_size = 2 + (seed % 2) as usize;
            let b = cfg.rollout.batch_prompts;
            let g = cfg.rollout.group_size;
            let mut coord = mock_coordinator(cfg, min_len, spread);
            let mut ds = Dataset::train(seed);
            // Two consecutive stages must both deliver exact batches.
            for _ in 0..2 {
                let out = coord
                    .rollout_stage(&mut ds)
                    .map_err(|e| format!("rollout failed: {e:#}"))?;
                check_groups(&out, b, g)?;
            }
            coord.shutdown();
            Ok(())
        },
    );
}

#[test]
fn prop_no_trajectory_is_lost_or_duplicated() {
    prop_check(
        "trajectory-conservation",
        10,
        |rng: &mut Rng| (2 + rng.below(10) as usize, rng.next_u64() % 997),
        |&(concurrency, seed)| {
            let cfg = base_cfg(RolloutMode::Copris, concurrency, seed);
            let mut coord = mock_coordinator(cfg, 8, 20);
            let mut ds = Dataset::train(seed);
            let mut seen = std::collections::HashSet::new();
            for _ in 0..3 {
                let out = coord
                    .rollout_stage(&mut ds)
                    .map_err(|e| format!("rollout failed: {e:#}"))?;
                for grp in &out.groups {
                    for t in &grp.done {
                        if !seen.insert(t.id) {
                            return Err(format!("trajectory {} harvested twice", t.id));
                        }
                    }
                }
            }
            coord.shutdown();
            Ok(())
        },
    );
}

#[test]
fn prop_kv_budget_preemption_preserves_correctness() {
    prop_check(
        "preemption-correctness",
        8,
        |rng: &mut Rng| (30 + rng.below(60) as usize, rng.next_u64() % 997),
        |&(kv_budget, seed)| {
            let mut cfg = base_cfg(RolloutMode::Copris, 8, seed);
            cfg.engine.kv_budget_blocks = kv_budget.div_ceil(cfg.engine.kv_block_size.max(1));
            let mut coord = mock_coordinator(cfg, 10, 20);
            let mut ds = Dataset::train(seed);
            let out = coord
                .rollout_stage(&mut ds)
                .map_err(|e| format!("rollout failed: {e:#}"))?;
            check_groups(&out, 4, 4)?;
            // Preempted partials may or may not be re-dispatched before the
            // stage ends; correctness is the exact-batch check above.
            coord.shutdown();
            Ok(())
        },
    );
}
