//! Continuous-batching golden + property tests.
//!
//! The correctness bar for the token-budget scheduler with chunked prefill
//! (`engine.step_token_budget > 0`): chunking may change *when* tokens are
//! computed, never *which* tokens. Pinned three ways, all with greedy
//! sampling and the determinism discipline of `rollout_golden.rs` (1
//! engine × 1 slot for the partial modes, positional mock scripts, no
//! mid-run weight syncs):
//!
//! - coordinator stages with the budget ON are bit-identical to the same
//!   stages with the budget OFF (legacy slot admission), across sync /
//!   copris / retained-resume;
//! - the chunked coordinator is bit-identical to the frozen pre-refactor
//!   `ReferenceCoordinator` oracle driving identically chunked engines;
//! - an engine-level property sweep of prompt lengths ±1 around
//!   `kv_block_size` and `step_token_budget` multiples (the chunk/block
//!   boundary lattice) reproduces the unchunked stream bit-exactly, for
//!   fresh prompts and replayed resumes alike.
//!
//! Plus the MockBackend chunk-boundary contract: in-order ingestion is
//! enforced bit-exactly, `start == 0` resets a preempted stage, and a
//! mid-chunk preemption/retention leaves the engine's page accounting
//! coverage-exact (every later install still validates).

use std::collections::HashMap;
use std::time::Duration;

use copris::config::{Config, RolloutMode};
use copris::coordinator::{Coordinator, ReferenceCoordinator, RolloutOutput};
use copris::engine::{
    Backend, Engine, EngineEvent, EngineOpts, EnginePool, KvCacheConfig, MockBackend,
    SamplingParams, WorkItem, WorkResult,
};
use copris::loadgen::{run_sim, ArrivalProcess, SimConfig};
use copris::tasks::Dataset;
use copris::testkit::prop_check;

const MAX_SEQ: usize = 96;

fn spawn_pool(
    engines: usize,
    slots: usize,
    step_budget: usize,
    seed: u64,
    min_len: usize,
    spread: usize,
    delay_us: u64,
) -> EnginePool {
    let opts = EngineOpts { kv: KvCacheConfig::unlimited(), step_token_budget: step_budget };
    EnginePool::spawn_opts(engines, slots, opts, seed, move |_id| {
        Box::new(move || {
            let mut b = MockBackend::new(slots, MAX_SEQ);
            b.min_len = min_len;
            b.spread = spread;
            if delay_us > 0 {
                b.decode_delay = Some(Duration::from_micros(delay_us));
            }
            Ok(b)
        })
    })
    .unwrap()
}

fn golden_cfg(mode: RolloutMode, step_budget: usize) -> Config {
    let mut cfg = Config::new("mock");
    cfg.rollout.mode = mode;
    cfg.rollout.batch_prompts = 3;
    cfg.rollout.group_size = 2;
    cfg.rollout.concurrency = 4;
    cfg.rollout.temperature = 0.0; // greedy → streams scripted, no RNG
    cfg.engine.engines = 1;
    cfg.engine.step_token_budget = step_budget;
    cfg.train.seed = 5;
    cfg
}

/// Canonical stage fingerprint (same shape as rollout_golden.rs).
type Fingerprint = Vec<(String, usize, Vec<(Vec<i32>, Vec<u32>)>)>;

fn fingerprint(out: &RolloutOutput) -> Fingerprint {
    let mut groups: Vec<_> = out
        .groups
        .iter()
        .map(|g| {
            let mut streams: Vec<(Vec<i32>, Vec<u32>)> = g
                .done
                .iter()
                .map(|t| {
                    (
                        t.tokens.clone(),
                        t.behavior_logprobs().iter().map(|l| l.to_bits()).collect(),
                    )
                })
                .collect();
            streams.sort();
            (g.task.prompt.clone(), g.target, streams)
        })
        .collect();
    groups.sort();
    groups
}

/// THE acceptance check, half one: chunked prefill on vs off is
/// bit-identical across sync and copris (retained-resume included —
/// retention is on by default, so copris stages stop, retain, and resume
/// partials across the three stages).
#[test]
fn chunked_on_off_stages_are_bit_identical() {
    for mode in [RolloutMode::Sync, RolloutMode::Copris] {
        let mut on_c = Coordinator::new(
            spawn_pool(1, 1, 5, 5, 4, 6, 200),
            golden_cfg(mode, 5),
            MAX_SEQ,
        );
        let mut off_c = Coordinator::new(
            spawn_pool(1, 1, 0, 5, 4, 6, 200),
            golden_cfg(mode, 0),
            MAX_SEQ,
        );
        let mut ds_on = Dataset::train(5);
        let mut ds_off = Dataset::train(5);
        for stage in 0..3 {
            let a = on_c.rollout_stage(&mut ds_on).unwrap();
            let b = off_c.rollout_stage(&mut ds_off).unwrap();
            assert_eq!(a.groups.len(), 3, "{mode:?} stage {stage}");
            assert_eq!(
                fingerprint(&a),
                fingerprint(&b),
                "chunked prefill changed a stream: mode {mode:?} stage {stage}"
            );
            if stage == 0 {
                assert!(
                    a.stats.prefill_chunks > 0,
                    "{mode:?}: budgeted arm must actually chunk"
                );
                assert!(a.stats.step_token_util > 0.0);
                assert_eq!(b.stats.prefill_chunks, 0, "legacy arm must not chunk");
                assert_eq!(b.stats.step_token_util, 0.0);
            }
        }
        on_c.shutdown();
        off_c.shutdown();
    }
}

/// THE acceptance check, half two: the chunked coordinator vs the frozen
/// pre-refactor oracle, both driving identically chunked engines — the
/// scheduler rewrite below the coordinator must be invisible to it.
#[test]
fn chunked_driver_matches_reference_oracle() {
    for mode in [RolloutMode::Sync, RolloutMode::NaivePartial, RolloutMode::Copris] {
        // The frozen reference never retains KV; run the live driver with
        // retention off so the comparison isolates the scheduler change.
        let mut cfg = golden_cfg(mode, 6);
        cfg.rollout.retain_kv = false;
        let mut new_c =
            Coordinator::new(spawn_pool(1, 1, 6, 5, 4, 6, 200), cfg.clone(), MAX_SEQ);
        let mut ref_c = ReferenceCoordinator::new(
            spawn_pool(1, 1, 6, 5, 4, 6, 200),
            cfg.clone(),
            MAX_SEQ,
        );
        let mut ds_new = Dataset::train(cfg.train.seed);
        let mut ds_ref = Dataset::train(cfg.train.seed);
        for stage in 0..3 {
            let a = new_c.rollout_stage(&mut ds_new).unwrap();
            let b = ref_c.rollout_stage(&mut ds_ref).unwrap();
            assert_eq!(
                fingerprint(&a),
                fingerprint(&b),
                "chunked driver diverged from reference: mode {mode:?} stage {stage}"
            );
        }
        new_c.shutdown();
        ref_c.shutdown();
    }
}

/// Retained-resume under chunking: a partial stopped with retention and
/// resumed via the affinity fast path skips ingestion entirely (zero
/// replay) — and the streams still match the unchunked arm bit-exactly.
/// Long scripts + slow decode guarantee mid-generation stops.
#[test]
fn retained_resume_with_chunking_stays_golden() {
    let run = |budget: usize| -> (Vec<Fingerprint>, usize, u64) {
        let mut cfg = golden_cfg(RolloutMode::Copris, budget);
        cfg.rollout.batch_prompts = 2;
        cfg.rollout.concurrency = 6;
        let mut coord =
            Coordinator::new(spawn_pool(1, 1, budget, 5, 16, 6, 300), cfg, MAX_SEQ);
        let mut ds = Dataset::train(5);
        let mut prints = Vec::new();
        let mut hits = 0usize;
        let mut resumed = 0u64;
        for _ in 0..4 {
            let out = coord.rollout_stage(&mut ds).unwrap();
            hits += out.stats.retained_hits;
            resumed += out.stats.resumed as u64;
            prints.push(fingerprint(&out));
        }
        coord.shutdown();
        (prints, hits, resumed)
    };
    let (on, hits_on, resumed_on) = run(5);
    let (off, _hits_off, _resumed_off) = run(0);
    assert_eq!(on, off, "retained-resume streams diverged under chunking");
    assert!(resumed_on > 0, "partial-heavy config must resume buffered partials");
    assert!(
        hits_on > 0,
        "single-engine copris with retention on must hit the affinity fast path"
    );
}

// ---------------------------------------------------------------------------
// Chunk/block boundary property sweep (engine level)
// ---------------------------------------------------------------------------

fn greedy_item(id: u64, prompt: Vec<i32>) -> WorkItem {
    WorkItem {
        request_id: id,
        prompt: prompt.into(),
        resume: vec![],
        max_total: MAX_SEQ,
        sampling: SamplingParams::greedy(),
        retain: None,
        prefix: None,
    }
}

fn drain(eng: &mut Engine<MockBackend>, max_steps: usize) -> Vec<WorkResult> {
    let mut out = Vec::new();
    for _ in 0..max_steps {
        if !eng.has_work() {
            break;
        }
        let mut ev = Vec::new();
        eng.step(&mut ev).unwrap();
        for e in ev {
            if let EngineEvent::Done { result, .. } = e {
                out.push(result);
            }
        }
    }
    out
}

fn chunked_engine(block_size: usize, budget: usize, slice_replay: bool) -> Engine<MockBackend> {
    let mut be = MockBackend::new(1, MAX_SEQ);
    be.min_len = 9;
    be.spread = 5;
    be.chunked_replay = slice_replay;
    let kv = KvCacheConfig {
        block_size,
        budget_blocks: 0,
        prefix_sharing: true,
        ..KvCacheConfig::default()
    };
    Engine::with_opts(0, be, EngineOpts { kv, step_token_budget: budget }, 1)
}

/// Prompt lengths sitting exactly on — and one off — every chunk/block
/// boundary must reproduce the unchunked stream bit-exactly, for fresh
/// prompts and for a stop→resume cycle (the resume replayed chunked via
/// `Backend::replay` slices in half the cases, per-token in the rest).
#[test]
fn prop_chunk_boundaries_pin_bit_identity() {
    let p_max = 24usize; // MockBackend default
    prop_check(
        "chunk-boundary-bit-identity",
        48,
        |rng| {
            let block_size = 2 + rng.below(7) as usize; // 2..=8
            let budget = 2 + rng.below(9) as usize; // 2..=10
            // A length on the boundary lattice of whichever granularity,
            // nudged by -1, 0, or +1.
            let base = if rng.below(2) == 0 { block_size } else { budget };
            let k = 1 + rng.below(3) as usize;
            let nudge = rng.below(3) as i64 - 1;
            let plen = ((base * k) as i64 + nudge).clamp(1, p_max as i64) as usize;
            let sliced = rng.below(2) == 0;
            let stop_after = 2 + rng.below(4) as usize;
            (block_size, budget, plen, sliced, stop_after)
        },
        |&(block_size, budget, plen, sliced, stop_after)| {
            let prompt: Vec<i32> = (0..plen).map(|t| 1 + (t as i32 % 9)).collect();

            // Oracle: unchunked, uninterrupted.
            let mut oracle = chunked_engine(block_size, 0, false);
            oracle.submit(greedy_item(1, prompt.clone())).unwrap();
            let want = drain(&mut oracle, 400);
            if want.len() != 1 {
                return Err(format!("oracle produced {} results", want.len()));
            }
            let want_toks = &want[0].new_tokens;
            let want_lps: Vec<u32> =
                want[0].new_logprobs.iter().map(|l| l.to_bits()).collect();

            // Fresh prompt, chunked.
            let mut eng = chunked_engine(block_size, budget, sliced);
            eng.submit(greedy_item(1, prompt.clone())).unwrap();
            let got = drain(&mut eng, 600);
            if got.len() != 1 {
                return Err(format!("chunked arm produced {} results", got.len()));
            }
            let got_lps: Vec<u32> =
                got[0].new_logprobs.iter().map(|l| l.to_bits()).collect();
            if &got[0].new_tokens != want_toks || got_lps != want_lps {
                return Err("fresh chunked stream diverged".into());
            }
            if eng.kv_tokens() != 0 || eng.kv_blocks() != 0 {
                return Err(format!(
                    "residency leak: {} tokens {} blocks",
                    eng.kv_tokens(),
                    eng.kv_blocks()
                ));
            }

            // Stop → resume cycle, chunked (no retention hint → full
            // replay, sliced or per-token).
            let mut eng = chunked_engine(block_size, budget, sliced);
            eng.submit(greedy_item(1, prompt.clone())).unwrap();
            let mut ev = Vec::new();
            for _ in 0..stop_after {
                eng.step(&mut ev).unwrap();
            }
            ev.clear();
            eng.stop_generation(&mut ev, false);
            let partial = ev
                .iter()
                .find_map(|e| match e {
                    EngineEvent::Done { result, .. } => Some(result.clone()),
                    _ => None,
                })
                .ok_or("no stopped partial")?;
            let mut it = greedy_item(1, prompt.clone());
            it.resume = partial.new_tokens.clone();
            eng.submit(it).unwrap();
            let rest = drain(&mut eng, 600);
            if rest.len() != 1 {
                return Err(format!("resume produced {} results", rest.len()));
            }
            let full_toks: Vec<i32> = partial
                .new_tokens
                .iter()
                .chain(rest[0].new_tokens.iter())
                .copied()
                .collect();
            let full_lps: Vec<u32> = partial
                .new_logprobs
                .iter()
                .chain(rest[0].new_logprobs.iter())
                .map(|l| l.to_bits())
                .collect();
            if &full_toks != want_toks || full_lps != want_lps {
                return Err(format!(
                    "stop/resume chunked stream diverged (partial {} toks, replayed {})",
                    partial.new_tokens.len(),
                    rest[0].replayed
                ));
            }
            if !partial.new_tokens.is_empty() && rest[0].replayed != partial.new_tokens.len()
            {
                return Err("replay count mismatch".into());
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------------
// MockBackend chunk-boundary contract
// ---------------------------------------------------------------------------

/// The mock enforces chunk boundaries bit-exactly: strictly in-order
/// ingestion, `start == 0` resets (the mid-chunk preemption contract),
/// out-of-order starts and oversized stages are hard errors, and replay
/// slices must start exactly at plen + replayed.
#[test]
fn mock_prefill_chunk_contract() {
    let mut be = MockBackend::new(2, MAX_SEQ);
    be.chunked_replay = true;
    let prompt = vec![1, 5, 6, 7, 8, 9];

    // In-order ingestion; the final chunk's logits equal whole-prompt
    // prefill's bit-exactly.
    assert!(be.prefill_chunk(0, &prompt[0..2], 0, false).unwrap().is_none());
    assert!(be.prefill_chunk(0, &prompt[2..4], 2, false).unwrap().is_none());
    let chunked = be.prefill_chunk(0, &prompt[4..6], 4, true).unwrap().expect("last chunk");
    let whole = be.prefill(1, &prompt).unwrap();
    assert_eq!(
        chunked.iter().map(|l| l.to_bits()).collect::<Vec<_>>(),
        whole.iter().map(|l| l.to_bits()).collect::<Vec<_>>(),
        "chunked prefill logits must match whole-prompt prefill"
    );

    // Boundary violations are hard errors.
    assert!(be.prefill_chunk(0, &prompt[0..2], 1, false).is_err(), "mid-stream start");
    assert!(be.prefill_chunk(0, &[], 0, false).is_err(), "empty chunk");
    be.prefill_chunk(0, &prompt[0..3], 0, false).unwrap(); // start=0 resets
    assert!(
        be.prefill_chunk(0, &prompt[0..2], 5, false).is_err(),
        "skip past staged length"
    );

    // A preemption reset (empty block table) discards the stage: the next
    // occupant must start at 0, and a stale continuation errors.
    be.prefill_chunk(0, &prompt[0..3], 0, false).unwrap();
    be.set_block_table(0, &[], 0, 4).unwrap();
    assert!(
        be.prefill_chunk(0, &prompt[3..5], 3, false).is_err(),
        "continuation across a reset must fail"
    );
    be.prefill_chunk(0, &prompt[0..3], 0, false).unwrap();

    // Replay slices: must follow a completed prefill, in order.
    be.prefill_chunk(1, &prompt, 0, true).unwrap().expect("prompt done");
    assert!(be.replay(1, &[4, 4], 7).is_err(), "slice must start at plen");
    let l1 = be.replay(1, &[4, 4], 6).unwrap().expect("chunked_replay on");
    let _ = l1;
    assert!(be.replay(1, &[4], 7).is_err(), "slice must start at plen + fed");
    be.replay(1, &[4], 8).unwrap().expect("in-order slice accepted");
}

/// Mid-chunk preemption under a tight block budget leaves the page table
/// coverage-exact: the engine keeps admitting and completing work with the
/// mock's install validation live the whole time, and every block is
/// accounted for at quiesce.
#[test]
fn mid_chunk_preemption_keeps_page_coverage_exact() {
    let mut be = MockBackend::new(2, MAX_SEQ);
    be.min_len = 18;
    be.spread = 4;
    // Tight budget: 6 blocks of 4 — long prompts must preempt/backpressure
    // while mid-ingestion slots hold partially charged chains.
    let kv = KvCacheConfig {
        block_size: 4,
        budget_blocks: 6,
        prefix_sharing: true,
        ..KvCacheConfig::default()
    };
    let mut eng = Engine::with_opts(0, be, EngineOpts { kv, step_token_budget: 5 }, 3);
    // Per-request (prompt, tokens generated so far) — the test plays the
    // coordinator's role and re-dispatches preempted work as resumes.
    let mut world: Vec<(Vec<i32>, Vec<i32>)> = Vec::new();
    for i in 0..6u64 {
        let plen = 6 + (i as usize * 7) % 17; // up to 23 ≤ p_max
        let prompt: Vec<i32> = (0..plen).map(|t| 1 + ((t + i as usize) as i32 % 9)).collect();
        world.push((prompt.clone(), Vec::new()));
        eng.submit(greedy_item(i, prompt)).unwrap();
    }
    let mut completed = 0usize;
    let mut preemptions = 0usize;
    let mut ev = Vec::new();
    for _ in 0..1500 {
        if !eng.has_work() {
            break;
        }
        // Any block-table contract violation is a hard step error.
        eng.step(&mut ev).unwrap();
        let mut requeue = Vec::new();
        for e in ev.drain(..) {
            if let EngineEvent::Done { result, .. } = e {
                let id = result.request_id as usize;
                world[id].1.extend_from_slice(&result.new_tokens);
                if result.reason.is_complete() {
                    completed += 1;
                } else {
                    // Preempted (possibly mid-chunk): resume everything
                    // generated so far, like the coordinator would.
                    preemptions += 1;
                    let mut it = greedy_item(result.request_id, world[id].0.clone());
                    it.resume = world[id].1.clone();
                    requeue.push(it);
                }
            }
        }
        for it in requeue {
            eng.submit(it).unwrap();
        }
    }
    assert_eq!(completed, 6, "all work completes despite budget pressure");
    assert!(preemptions > 0 || eng.queued() == 0, "run exercised the pressure path");
    assert_eq!(eng.kv_tokens(), 0, "coverage-exact: no resident tokens at quiesce");
    assert_eq!(eng.kv_blocks(), 0, "coverage-exact: no leaked blocks at quiesce");
}

// ---------------------------------------------------------------------------
// Overload / shedding arm (the SLO-harness satellite)
// ---------------------------------------------------------------------------

/// Under KV-budget overload the engine sheds residency cheapest-first —
/// shared-prefix registry entries, then retained slots, then live-slot
/// preemption — and never preempts its last live slot. The test pins the
/// ORDER of the first transition of each tier, not just that each tier
/// eventually empties, and then drains every request (preempted work
/// resumed like the coordinator would) to show pressure never strands
/// work.
#[test]
fn overload_shed_order_is_cheapest_first() {
    let mut be = MockBackend::new(3, MAX_SEQ);
    be.min_len = 40;
    be.spread = 1; // long scripts: sequences keep growing into the budget
    let kv = KvCacheConfig {
        block_size: 4,
        budget_blocks: 10,
        prefix_sharing: true,
        ..KvCacheConfig::default()
    };
    let mut eng = Engine::with_kv(0, be, kv, 1);

    // Tier setup: one stopped partial leaves a retained slot AND a
    // shared-prefix registry entry behind.
    let mut it = greedy_item(1, vec![1, 8, 8, 8]);
    it.prefix = Some(7);
    eng.submit(it).unwrap();
    let mut ev = Vec::new();
    for _ in 0..4 {
        eng.step(&mut ev).unwrap();
    }
    ev.clear();
    eng.stop_generation(&mut ev, true);
    let partial = ev
        .iter()
        .find_map(|e| match e {
            EngineEvent::Done { result, .. } => Some(result.clone()),
            _ => None,
        })
        .expect("flushed partial");
    assert_eq!(eng.retained(), 1);
    assert_eq!(eng.prefix_entries(), 1);

    // Two fresh long-running sequences grow the live working set past the
    // budget; watch the first transition of each shed tier.
    eng.submit(greedy_item(2, vec![1, 4, 4, 4])).unwrap();
    eng.submit(greedy_item(3, vec![1, 5, 5, 5])).unwrap();
    let (mut t_prefix, mut t_retained, mut t_preempt) = (None, None, None);
    let mut world: HashMap<u64, (Vec<i32>, Vec<i32>)> = HashMap::new();
    world.insert(2, (vec![1, 4, 4, 4], Vec::new()));
    world.insert(3, (vec![1, 5, 5, 5], Vec::new()));
    let mut completed = 0usize;
    for step in 0..400 {
        if !eng.has_work() {
            break;
        }
        ev.clear();
        eng.step(&mut ev).unwrap();
        if t_prefix.is_none() && eng.prefix_entries() == 0 {
            t_prefix = Some(step);
        }
        if t_retained.is_none() && eng.retained_evictions > 0 {
            t_retained = Some(step);
            assert!(t_prefix.is_some(), "retained slot shed while the registry had entries");
        }
        if t_preempt.is_none() && eng.preemptions() > 0 {
            t_preempt = Some(step);
            assert!(t_retained.is_some(), "live slot preempted while retained KV was parked");
        }
        assert!(eng.busy() >= 1, "engine must never preempt its last live slot");
        let mut requeue = Vec::new();
        for e in ev.drain(..) {
            if let EngineEvent::Done { result, .. } = e {
                let id = result.request_id;
                let (_, gen) = world.get_mut(&id).unwrap();
                gen.extend_from_slice(&result.new_tokens);
                if result.reason.is_complete() {
                    completed += 1;
                } else {
                    let (prompt, gen) = &world[&id];
                    let mut it = greedy_item(id, prompt.clone());
                    it.resume = gen.clone();
                    requeue.push(it);
                }
            }
        }
        for it in requeue {
            eng.submit(it).unwrap();
        }
    }
    assert_eq!(completed, 2, "both live sequences complete despite budget pressure");
    let (tp, tr, tv) = (
        t_prefix.expect("pressure never evicted the prefix registry"),
        t_retained.expect("pressure never evicted the retained slot"),
        t_preempt.expect("pressure never preempted a live slot"),
    );
    assert!(tp <= tr && tr <= tv, "shed order violated: prefix {tp}, retained {tr}, preempt {tv}");

    // Epilogue: the stopped-and-evicted partial resumes via replay (the
    // retain hint is stale by construction) and still completes.
    let mut it = greedy_item(1, vec![1, 8, 8, 8]);
    it.resume = partial.new_tokens.clone();
    eng.submit(it).unwrap();
    let done = drain(&mut eng, 400);
    assert_eq!(done.len(), 1);
    assert!(done[0].reason.is_complete(), "evicted partial must still complete via replay");
}

/// Decode lanes are never dropped: under a step-token budget saturated by
/// a long chunked prefill, every sequence already decoding still advances
/// by exactly one token per step. Prefill pressure can slow ingestion,
/// never starve decode.
#[test]
fn decode_lanes_never_dropped_under_prefill_pressure() {
    let mut be = MockBackend::new(4, MAX_SEQ);
    be.min_len = 30;
    be.spread = 1;
    let opts = EngineOpts { kv: KvCacheConfig::unlimited(), step_token_budget: 4 };
    let mut eng = Engine::with_opts(0, be, opts, 1);
    for i in 0..3u64 {
        eng.submit(greedy_item(i, vec![1, 2 + i as i32])).unwrap();
    }
    // Warm up until all three short prompts are decoding.
    let mut ev = Vec::new();
    for _ in 0..10 {
        eng.step(&mut ev).unwrap();
        ev.clear();
        if eng.slot_progress().iter().filter(|&&(_, n)| n >= 2).count() == 3 {
            break;
        }
    }
    let decoding = eng.slot_progress().iter().filter(|&&(_, n)| n >= 2).count();
    assert_eq!(decoding, 3, "warmup must leave three decode lanes live");

    // A 20-token prompt now competes for the 4-token budget: 3 tokens go
    // to decode, leaving 1/step of chunked prefill.
    let long: Vec<i32> = (0..20).map(|t| 1 + (t % 9)).collect();
    eng.submit(greedy_item(9, long)).unwrap();
    for step in 0..10 {
        let before: HashMap<u64, usize> = eng.slot_progress().into_iter().collect();
        ev.clear();
        eng.step(&mut ev).unwrap();
        let after: HashMap<u64, usize> = eng.slot_progress().into_iter().collect();
        for (&rid, &n) in &before {
            if n >= 1 && rid != 9 {
                assert_eq!(
                    after.get(&rid).copied(),
                    Some(n + 1),
                    "decode lane {rid} stalled at step {step} under prefill pressure"
                );
            }
        }
    }
    assert!(
        eng.prefill_chunks > 0 || eng.queued() > 0,
        "the long prompt must actually be ingesting in chunks"
    );
}

/// The open-loop lockstep sim under sustained overload WITH continuous
/// batching and a tight KV budget: the bounded admission queue sheds
/// (the structured overload signal) instead of deadlocking, every
/// arrival is accounted for, and the engine/collector preemption
/// ledgers agree.
#[test]
fn open_loop_overload_with_chunking_conserves_and_terminates() {
    let cfg = SimConfig {
        engines: 2,
        slots: 2,
        kv_budget_blocks: 24,
        kv_block_size: 8,
        step_token_budget: 16,
        queue_cap: 6,
        requests: 120,
        seed: 3,
        process: ArrivalProcess::Poisson { rate_rps: 3_000.0 },
        ..SimConfig::default()
    };
    let r = run_sim(&cfg);
    assert!(r.completed_all, "bounded queue + chunked prefill must not deadlock");
    assert_eq!(r.report.arrived, 120);
    assert_eq!(
        r.report.completed + r.report.shed,
        r.report.arrived,
        "every arrival either completes or is shed — none lost, none duplicated"
    );
    assert!(r.report.shed > 0, "a 6-deep queue at 3000 rps must shed");
    assert!(r.report.queue_depth_peak <= 6, "queue bound violated");
    assert_eq!(
        r.engine_preemptions, r.report.preemptions,
        "engine and SLO-collector preemption ledgers must agree"
    );
    // Replays bit-identically even under overload + preemption churn.
    let again = run_sim(&cfg);
    assert_eq!(r.report, again.report);
}
