//! Runtime integration: execute the real `tiny` artifacts through PJRT and
//! check numerics/invariants against what python/tests verified for the
//! same HLO. Requires `make artifacts` (artifacts/tiny must exist) — tests
//! are skipped (not failed) when artifacts are missing so `cargo test`
//! works pre-build.

use copris::model::{ModelRuntime, TrainState};
use copris::tokenizer::Tokenizer;

fn open_tiny() -> Option<ModelRuntime> {
    if !std::path::Path::new("artifacts/tiny/manifest.json").exists() {
        eprintln!("skipping: artifacts/tiny missing (run `make artifacts`)");
        return None;
    }
    Some(ModelRuntime::open("artifacts", "tiny").expect("open tiny runtime"))
}

#[test]
fn manifest_shapes_are_consistent() {
    let Some(rt) = open_tiny() else { return };
    let s = &rt.spec;
    assert_eq!(s.state_elems, 3 * s.n_params);
    assert_eq!(s.engine_state_elems, s.slots * s.vocab + s.kv_elems);
    assert_eq!(s.grad_elems, s.n_params + s.n_metrics);
    assert_eq!(s.vocab, copris::tokenizer::VOCAB);
}

#[test]
fn init_is_deterministic_and_moments_zero() {
    let Some(mut rt) = open_tiny() else { return };
    let n = rt.spec.n_params;
    let s1 = rt.init_state(7).unwrap();
    let s2 = rt.init_state(7).unwrap();
    let a = rt.device.read_all_f32(&s1, 3 * n).unwrap();
    let b = rt.device.read_all_f32(&s2, 3 * n).unwrap();
    assert_eq!(a, b, "same seed must give identical params");
    assert!(a[n..].iter().all(|&x| x == 0.0), "adam moments start at zero");
    let s3 = rt.init_state(8).unwrap();
    let c = rt.device.read_all_f32(&s3, 3 * n).unwrap();
    assert_ne!(a, c, "different seeds must differ");
}

#[test]
fn read_params_extract_matches_full_state_prefix() {
    let Some(mut rt) = open_tiny() else { return };
    let n = rt.spec.n_params;
    let state = rt.init_state(3).unwrap();
    let full = rt.device.read_all_f32(&state, 3 * n).unwrap();
    let params = rt.params_to_host(&state).unwrap();
    assert_eq!(&full[..n], params.as_slice());
}

#[test]
fn prefill_then_decode_produces_finite_logits_and_updates_kv() {
    let Some(mut rt) = open_tiny() else { return };
    let spec = rt.spec.clone();
    let state = rt.init_state(5).unwrap();
    let params_host = rt.params_to_host(&state).unwrap();
    let params = rt.upload_params(&params_host).unwrap();
    let es = rt.fresh_engine_state().unwrap();

    let tk = Tokenizer::new();
    let prompt = tk.encode_prompt("3+4=");
    let (es, logits) = rt.prefill(&params, &es, &prompt, 1).unwrap();
    assert_eq!(logits.len(), spec.vocab);
    assert!(logits.iter().all(|x| x.is_finite()));

    // Decode a few steps in slot 1; KV state must affect later steps.
    let mut es = es;
    let mut toks = vec![0i32; spec.slots];
    let mut pos = vec![0i32; spec.slots];
    toks[1] = 5;
    pos[1] = prompt.len() as i32;
    let (es2, l1) = rt.decode(&params, &es, &toks, &pos).unwrap();
    es = es2;
    let row1 = l1[spec.vocab..2 * spec.vocab].to_vec();
    assert!(row1.iter().all(|x| x.is_finite()));
    toks[1] = 6;
    pos[1] += 1;
    let (_es3, l2) = rt.decode(&params, &es, &toks, &pos).unwrap();
    let row2 = l2[spec.vocab..2 * spec.vocab].to_vec();
    assert_ne!(row1, row2, "KV state must affect subsequent steps");
}

#[test]
fn decode_greedy_matches_logprob_scoring() {
    // Generate greedily via prefill+decode, then score the same sequence
    // with the logprob artifact: greedy tokens must be modal — their
    // log-prob exceeds ln(1/V) — cross-artifact consistency of the
    // rollout and training paths over the SAME weights.
    let Some(mut rt) = open_tiny() else { return };
    let spec = rt.spec.clone();
    let state = rt.init_state(11).unwrap();
    let params_host = rt.params_to_host(&state).unwrap();
    let params = rt.upload_params(&params_host).unwrap();
    let es = rt.fresh_engine_state().unwrap();

    let prompt: Vec<i32> = vec![1, 10, 11, 12];
    let slot = 2usize;
    let (mut es, logits) = rt.prefill(&params, &es, &prompt, slot).unwrap();
    let mut seq = prompt.clone();
    let mut next = argmax(&logits) as i32;
    seq.push(next);
    let n_steps = 6;
    for i in 0..n_steps {
        let mut toks = vec![0i32; spec.slots];
        let mut pos = vec![0i32; spec.slots];
        toks[slot] = next;
        pos[slot] = (prompt.len() + i) as i32;
        let (es2, l) = rt.decode(&params, &es, &toks, &pos).unwrap();
        es = es2;
        next = argmax(&l[slot * spec.vocab..(slot + 1) * spec.vocab]) as i32;
        seq.push(next);
    }

    // Teacher-forced scoring of the same sequence.
    let (b, t) = (spec.b_micro, spec.t_train);
    let mut tokens = vec![0i32; b * t];
    tokens[..seq.len()].copy_from_slice(&seq);
    let (lp, ent) = rt.logprob(&state, &tokens).unwrap();
    for i in (prompt.len() - 1)..(prompt.len() - 1 + n_steps) {
        assert!(lp[i].is_finite());
        assert!(
            lp[i] > (1.0 / spec.vocab as f32).ln(),
            "greedy token lp {} at {i} below uniform",
            lp[i]
        );
        assert!(ent[i] >= -1e-4 && ent[i] <= (spec.vocab as f32).ln() + 1e-4);
    }
}

#[test]
fn sft_step_decreases_loss_through_update_artifact() {
    let Some(mut rt) = open_tiny() else { return };
    let spec = rt.spec.clone();
    let mut state = TrainState::init(&mut rt, 2).unwrap();
    let (b, t) = (spec.b_micro, spec.t_train);
    // A fixed repetitive batch the model can memorize quickly.
    let mut tokens = Vec::with_capacity(b * t);
    for r in 0..b {
        for i in 0..t {
            tokens.push(4 + ((i + r) % 6) as i32);
        }
    }
    let mask = vec![1f32; b * (t - 1)];
    let mut losses = Vec::new();
    for _ in 0..8 {
        let (g, m) = rt.sft_grad(&state.buffer, &tokens, &mask).unwrap();
        losses.push(m.loss_sum as f64 / m.token_count as f64);
        state.apply_update(&mut rt, &g, 3e-3, 1.0 / m.token_count).unwrap();
    }
    assert!(
        losses.last().unwrap() < &(losses[0] - 0.05),
        "loss should drop: {losses:?}"
    );
    assert_eq!(state.step, 8);
}

#[test]
fn grpo_grad_onpolicy_has_unit_ratio() {
    let Some(mut rt) = open_tiny() else { return };
    let spec = rt.spec.clone();
    let state = rt.init_state(4).unwrap();
    let (b, t) = (spec.b_micro, spec.t_train);
    let tokens: Vec<i32> = (0..b * t).map(|i| 4 + (i % 9) as i32).collect();
    let mut mask = vec![0f32; b * (t - 1)];
    for r in 0..b {
        for i in 5..25 {
            mask[r * (t - 1) + i] = 1.0;
        }
    }
    let (lp, _) = rt.logprob(&state, &tokens).unwrap();
    let behav: Vec<f32> = lp.clone();
    let adv = vec![1.0f32; b];
    let (_g, m) = rt.grad(&state, &tokens, &mask, &behav, &adv).unwrap();
    let ratio_mean = m.ratio_sum / m.token_count;
    assert!((ratio_mean - 1.0).abs() < 1e-3, "on-policy ratio {ratio_mean}");
    assert_eq!(m.clip_sum, 0.0);
    assert!(m.grad_norm > 0.0);
}

#[test]
fn accum_is_linear() {
    let Some(mut rt) = open_tiny() else { return };
    let gn = rt.spec.grad_elems;
    let a: Vec<f32> = (0..gn).map(|i| (i % 7) as f32).collect();
    let b: Vec<f32> = (0..gn).map(|i| (i % 3) as f32).collect();
    let ab = rt.device.upload_f32(&a).unwrap();
    let bb = rt.device.upload_f32(&b).unwrap();
    let out = rt.accum(&ab, &bb, 0.5).unwrap();
    let got = rt.device.read_all_f32(&out, gn).unwrap();
    for i in (0..gn).step_by(997) {
        assert!((got[i] - (a[i] + 0.5 * b[i])).abs() < 1e-6);
    }
}

#[test]
fn checkpoint_roundtrip() {
    let Some(mut rt) = open_tiny() else { return };
    let n = rt.spec.state_elems;
    let mut state = TrainState::init(&mut rt, 9).unwrap();
    state.step = 42;
    let dir = std::env::temp_dir().join("copris-ckpt-test");
    let path = dir.join("t.ckpt");
    state.save(&mut rt, &path).unwrap();
    let loaded = TrainState::load(&mut rt, &path).unwrap();
    assert_eq!(loaded.step, 42);
    let a = rt.device.read_all_f32(&state.buffer, n).unwrap();
    let b = rt.device.read_all_f32(&loaded.buffer, n).unwrap();
    assert_eq!(a, b);
    std::fs::remove_dir_all(&dir).ok();
}

fn argmax(xs: &[f32]) -> usize {
    xs.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap()
}

#[test]
fn replay_chunk_matches_sequential_decode() {
    // The rust-side resumption contract: chunked replay == token-by-token
    // decode (same logits for the next sample).
    let Some(mut rt) = open_tiny() else { return };
    let spec = rt.spec.clone();
    let state = rt.init_state(13).unwrap();
    let params_host = rt.params_to_host(&state).unwrap();
    let params = rt.upload_params(&params_host).unwrap();

    let prompt: Vec<i32> = vec![1, 8, 9, 10];
    let resume: Vec<i32> = vec![5, 6, 7, 8, 9];
    let slot = 0usize;

    // Path A: sequential decode.
    let es = rt.fresh_engine_state().unwrap();
    let (mut es_a, _) = rt.prefill(&params, &es, &prompt, slot).unwrap();
    let mut logits_a = vec![];
    for (i, &tok) in resume.iter().enumerate() {
        let mut toks = vec![0i32; spec.slots];
        let mut pos = vec![0i32; spec.slots];
        toks[slot] = tok;
        pos[slot] = (prompt.len() + i) as i32;
        let (es2, l) = rt.decode(&params, &es_a, &toks, &pos).unwrap();
        es_a = es2;
        logits_a = l[slot * spec.vocab..(slot + 1) * spec.vocab].to_vec();
    }

    // Path B: one chunked replay call.
    let es = rt.fresh_engine_state().unwrap();
    let (es_b, _) = rt.prefill(&params, &es, &prompt, slot).unwrap();
    let (_es_b2, logits_b) = rt.replay(&params, &es_b, &resume, prompt.len(), slot).unwrap();

    for (a, b) in logits_a.iter().zip(logits_b.iter()) {
        assert!((a - b).abs() < 2e-3, "replay logits diverge: {a} vs {b}");
    }
}
