//! Golden driver-equivalence + stage-pipelining tests.
//!
//! The state-machine coordinator (`begin_stage`/`pump`/`finish_stage`) must
//! produce BIT-IDENTICAL stage outputs to the frozen pre-refactor blocking
//! coordinator (`ReferenceCoordinator`) for sync / naive / copris. The
//! comparison is made exactly deterministic:
//! - greedy sampling (temperature 0) → mock token streams are fully
//!   scripted by (prompt, params_epoch), independent of thread timing;
//! - 1 engine × 1 decode slot → single-file processing, so completion
//!   order equals dispatch order;
//! - no weight syncs inside a comparison run → a partial cut at a
//!   timing-dependent position resumes to the *same* final stream (the
//!   mock script is positional), so drain races are invisible;
//! - `GroupBook::groups_with_deficit` breaks ties by group id;
//! - kv_budget 0 + 1 slot bounds tokened drain leftovers to ≤ 1 (buffer
//!   pops sit at the queue head and are admitted long before a stage can
//!   end), so the frozen reference's HashMap-ordered leftover parking
//!   cannot order-diverge from the driver's sorted parking. Do not add a
//!   kv_budget or multi-slot partial-mode arm to the bit-identical
//!   comparison without revisiting that bound.
//!
//! Plus: the eval-isolation fix (training partials never stolen by
//! `run_fixed_sync`), the `RolloutStats::resumed` fix, the pipelined
//! mode's exact-B delivery / multi-segment behaviour-logprob / wall-clock
//! overlap win, and the fully-async stream's correctness pins: staleness-0
//! async ≡ the pipelined stage sequence bit-for-bit, and the bounded-
//! staleness invariant (no segment spans more than `max_staleness` syncs).

use std::sync::Arc;
use std::time::{Duration, Instant};

use copris::config::{Config, ExecMode, RolloutMode};
use copris::coordinator::{Coordinator, ReferenceCoordinator, RolloutOutput};
use copris::engine::{EnginePool, MockBackend, SamplingParams};
use copris::exp::pipesim::{run as pipesim, PipeSimOpts};
use copris::tasks::Dataset;

const MAX_SEQ: usize = 96;

fn spawn_pool(
    engines: usize,
    slots: usize,
    seed: u64,
    min_len: usize,
    spread: usize,
    delay_us: u64,
) -> EnginePool {
    EnginePool::spawn(engines, slots, 0, seed, move |_id| {
        Box::new(move || {
            let mut b = MockBackend::new(slots, MAX_SEQ);
            b.min_len = min_len;
            b.spread = spread;
            if delay_us > 0 {
                b.decode_delay = Some(Duration::from_micros(delay_us));
            }
            Ok(b)
        })
    })
    .unwrap()
}

fn golden_cfg(mode: RolloutMode) -> Config {
    let mut cfg = Config::new("mock");
    cfg.rollout.mode = mode;
    cfg.rollout.batch_prompts = 3;
    cfg.rollout.group_size = 2;
    // < B·G so the naive wave exhausts and the re-wave fallback runs.
    cfg.rollout.concurrency = 4;
    cfg.rollout.temperature = 0.0; // greedy → streams scripted, no RNG
    cfg.engine.engines = 1;
    cfg.train.seed = 5;
    cfg
}

/// Canonical stage fingerprint, invariant to completion order and
/// trajectory ids: groups sorted by task prompt; per group the sorted
/// multiset of (token stream, behaviour-logprob bits).
type Fingerprint = Vec<(String, usize, Vec<(Vec<i32>, Vec<u32>)>)>;

fn fingerprint(out: &RolloutOutput) -> Fingerprint {
    let mut groups: Vec<_> = out
        .groups
        .iter()
        .map(|g| {
            let mut streams: Vec<(Vec<i32>, Vec<u32>)> = g
                .done
                .iter()
                .map(|t| {
                    (
                        t.tokens.clone(),
                        t.behavior_logprobs().iter().map(|l| l.to_bits()).collect(),
                    )
                })
                .collect();
            streams.sort();
            (g.task.prompt.clone(), g.target, streams)
        })
        .collect();
    groups.sort();
    groups
}

/// THE acceptance check: three stages per mode, new state-machine driver
/// vs frozen pre-refactor coordinator, bit-identical group outputs.
#[test]
fn state_machine_matches_reference_across_modes() {
    for mode in [RolloutMode::Sync, RolloutMode::NaivePartial, RolloutMode::Copris] {
        let cfg = golden_cfg(mode);
        let mut new_c = Coordinator::new(
            spawn_pool(1, 1, cfg.train.seed, 4, 6, 200),
            cfg.clone(),
            MAX_SEQ,
        );
        let mut ref_c = ReferenceCoordinator::new(
            spawn_pool(1, 1, cfg.train.seed, 4, 6, 200),
            cfg.clone(),
            MAX_SEQ,
        );
        let mut ds_new = Dataset::train(cfg.train.seed);
        let mut ds_ref = Dataset::train(cfg.train.seed);
        for stage in 0..3 {
            let a = new_c.rollout_stage(&mut ds_new).unwrap();
            let b = ref_c.rollout_stage(&mut ds_ref).unwrap();
            assert_eq!(a.groups.len(), cfg.rollout.batch_prompts, "{mode:?} stage {stage}");
            assert_eq!(
                fingerprint(&a),
                fingerprint(&b),
                "driver diverged from reference: mode {mode:?} stage {stage}"
            );
            for grp in &a.groups {
                for t in &grp.done {
                    assert!(t.complete && t.invariant_ok());
                }
            }
        }
        new_c.shutdown();
        ref_c.shutdown();
    }
}

/// Sync mode is set-deterministic even multi-engine/multi-slot (all B·G
/// dispatched upfront, all complete): harvested groups must match the
/// reference after canonical sorting.
#[test]
fn sync_multi_engine_matches_reference() {
    let mut cfg = golden_cfg(RolloutMode::Sync);
    cfg.engine.engines = 2;
    cfg.rollout.batch_prompts = 4;
    let mut new_c =
        Coordinator::new(spawn_pool(2, 4, cfg.train.seed, 3, 8, 100), cfg.clone(), MAX_SEQ);
    let mut ref_c = ReferenceCoordinator::new(
        spawn_pool(2, 4, cfg.train.seed, 3, 8, 100),
        cfg.clone(),
        MAX_SEQ,
    );
    let mut ds_new = Dataset::train(cfg.train.seed);
    let mut ds_ref = Dataset::train(cfg.train.seed);
    for stage in 0..2 {
        let a = new_c.rollout_stage(&mut ds_new).unwrap();
        let b = ref_c.rollout_stage(&mut ds_ref).unwrap();
        assert_eq!(fingerprint(&a), fingerprint(&b), "sync multi-engine stage {stage}");
    }
    new_c.shutdown();
    ref_c.shutdown();
}

fn partial_heavy_cfg() -> Config {
    let mut cfg = Config::new("mock");
    cfg.rollout.mode = RolloutMode::Copris;
    cfg.rollout.batch_prompts = 2;
    cfg.rollout.group_size = 2;
    cfg.rollout.concurrency = 8;
    cfg.engine.engines = 1;
    cfg.train.seed = 7;
    cfg
}

/// Satellite fix: `run_fixed_sync` must NOT pop carried-over training
/// partials from the shared buffer (the old driver generated — and
/// completed into the training book — buffered partials under eval).
#[test]
fn eval_leaves_training_partials_untouched() {
    let cfg = partial_heavy_cfg();
    // Long scripts + slow decode → in-flight partials at early termination.
    let mut coord = Coordinator::new(spawn_pool(1, 4, 7, 20, 30, 500), cfg, MAX_SEQ);
    let mut ds = Dataset::train(7);
    let _ = coord.rollout_stage(&mut ds).unwrap();
    let before = coord.buffered();
    if before == 0 {
        // Vanishingly unlikely with these script lengths; not an error.
        coord.shutdown();
        return;
    }
    let suite = &copris::tasks::eval_suites()[0];
    let tasks = suite.tasks(4, 9);
    let groups = coord.run_fixed_sync(&tasks, 2, SamplingParams::default()).unwrap();
    assert_eq!(groups.len(), 4);
    for g in &groups {
        assert!(g.is_complete());
    }
    assert_eq!(
        coord.buffered(),
        before,
        "eval consumed buffered TRAINING partials"
    );
    coord.shutdown();
}

/// Companion: the frozen reference really has the bug the fix pins (its
/// eval loop drains the whole shared buffer).
#[test]
fn reference_eval_steals_training_partials() {
    let cfg = partial_heavy_cfg();
    let mut coord = ReferenceCoordinator::new(spawn_pool(1, 4, 7, 20, 30, 500), cfg, MAX_SEQ);
    let mut ds = Dataset::train(7);
    let _ = coord.rollout_stage(&mut ds).unwrap();
    let before = coord.buffered();
    if before == 0 {
        coord.shutdown();
        return;
    }
    let suite = &copris::tasks::eval_suites()[0];
    let tasks = suite.tasks(4, 9);
    let _ = coord.run_fixed_sync(&tasks, 2, SamplingParams::default()).unwrap();
    assert_eq!(coord.buffered(), 0, "pre-refactor eval drains the buffer");
    coord.shutdown();
}

/// Satellite fix: `RolloutStats::resumed` counts buffer pops (it was
/// never incremented before — "set by caller" that no caller set).
/// KV retention is disabled here so the companion `replayed_tokens`
/// assertion exercises the replay accounting it was written for (with
/// retention on, resumes hit retained KV and the cost moves to
/// `replay_tokens_saved` — pinned by tests/retained_golden.rs).
#[test]
fn resumed_counts_buffer_pops() {
    let mut cfg = partial_heavy_cfg();
    cfg.rollout.retain_kv = false;
    let mut coord = Coordinator::new(spawn_pool(1, 4, 7, 15, 30, 300), cfg, MAX_SEQ);
    let mut ds = Dataset::train(7);
    let out1 = coord.rollout_stage(&mut ds).unwrap();
    assert_eq!(out1.stats.resumed, 0, "stage 1 has nothing to resume");
    if coord.buffered() == 0 {
        coord.shutdown();
        return;
    }
    let buffered = coord.buffered();
    let out2 = coord.rollout_stage(&mut ds).unwrap();
    assert!(
        out2.stats.resumed > 0,
        "buffered partials ({buffered}) resumed but not counted: {:?}",
        out2.stats
    );
    assert!(out2.stats.replayed_tokens > 0);
    coord.shutdown();
}

/// Pipelined vs serial CoPRIS at equal batch count: exact-B delivery both
/// arms, measurable wall-clock win for the pipelined arm (acceptance
/// criterion — mock decode delay is the "non-trivial per-step delay").
#[test]
fn pipelined_copris_beats_serial_wall_clock_at_equal_batches() {
    let mut opts = PipeSimOpts::default();
    opts.steps = 6;
    opts.train_secs = 0.08;
    // 2 ms/step decode → rollout ≈ train window, maximising the absolute
    // serial-vs-pipelined gap (robust against CI timer noise).
    opts.decode_delay = Duration::from_millis(2);
    let (serial, s_outs) = pipesim(&opts, false).unwrap();
    let (piped, p_outs) = pipesim(&opts, true).unwrap();
    let b = opts.cfg.rollout.batch_prompts;
    let g = opts.cfg.rollout.group_size;
    for outs in [&s_outs, &p_outs] {
        assert_eq!(outs.len(), opts.steps);
        for out in outs.iter() {
            assert_eq!(out.groups.len(), b, "exact-B delivery");
            for grp in &out.groups {
                assert!(grp.done.len() >= g, "incomplete group harvested");
                for t in &grp.done {
                    assert!(t.complete && t.invariant_ok());
                }
            }
        }
    }
    assert_eq!(serial.groups, piped.groups, "equal total batches");
    assert!(serial.samples >= opts.steps * b * g);
    assert!(piped.samples >= opts.steps * b * g);
    assert!(piped.overlap_secs > 0.0, "no overlap recorded: {piped:?}");
    assert!(
        piped.wall < serial.wall,
        "pipelined ({:.3}s) not faster than serial ({:.3}s) at equal batches",
        piped.wall,
        serial.wall
    );
}

/// Pipelined mode: mid-flight weight syncs give resumed trajectories
/// another version segment; their behaviour log-probs must be the correct
/// multi-segment concat (Eq. 6), with non-decreasing segment versions.
#[test]
fn pipelined_version_lag_trajectories_carry_multi_segment_behav_lp() {
    let mut opts = PipeSimOpts::default();
    opts.steps = 5;
    // Long scripts → partials at every early termination; a short train
    // window → resumed partials (which must replay their long prefix)
    // finish AFTER the mid-flight sync, under the new version.
    opts.min_len = 35;
    opts.spread = 14;
    opts.train_secs = 0.03;
    let (summary, outs) = pipesim(&opts, true).unwrap();
    let mut multi_segment = 0usize;
    for out in &outs {
        for grp in &out.groups {
            for t in &grp.done {
                assert_eq!(
                    t.behavior_logprobs().len(),
                    t.tokens.len(),
                    "Eq. 6 concat length"
                );
                assert!(t.invariant_ok());
                let mut prev = t.born_version;
                for s in &t.segments {
                    assert!(
                        s.policy_version >= prev,
                        "segment versions must be non-decreasing"
                    );
                    prev = s.policy_version;
                }
                if t.n_stages() > 1 {
                    multi_segment += 1;
                    let last = t.segments.last().unwrap().policy_version;
                    assert!(last > t.born_version, "multi-segment implies version lag");
                    assert!(t.offpolicy_tokens(last) > 0);
                }
            }
        }
    }
    assert!(
        multi_segment > 0,
        "no multi-segment trajectories despite mid-flight syncs: {summary:?}"
    );
    assert!(summary.lagged_trajectories >= multi_segment);
    assert!(summary.partials_buffered > 0);
    assert!(summary.resumed > 0);
}

// ------------------------------------------------------- fully-async stream

fn async_cfg(max_staleness: usize) -> Config {
    let mut cfg = golden_cfg(RolloutMode::Copris);
    cfg.rollout.execution = ExecMode::Async;
    cfg.rollout.max_staleness = max_staleness;
    cfg
}

/// Tentpole acceptance pin: **staleness-0 async is bit-identical to the
/// pipelined stage sequence.** At S = 0 every `prepare_sync` cuts ALL
/// in-flight work through the same stop-and-drain machinery that stage
/// early-termination uses, so the async schedule (pump → take → cut →
/// sync → refill) collapses to exactly the pipelined schedule with the
/// sync landing between stages — i.e. the serial CoPRIS stage sequence,
/// which the pipelined driver reproduces per the goldens above.
///
/// Unlike the driver-vs-reference goldens, weight syncs DO happen between
/// batches here. The determinism trick is constant params: every sync
/// broadcasts the same value, so the mock backend's `params_epoch` never
/// changes and token scripts stay purely prompt-determined — a cut landing
/// at a timing-dependent position resumes to the same final stream, and
/// 1 engine × 1 slot keeps completion order equal to dispatch order.
#[test]
fn async_staleness_zero_bit_identical_to_pipelined() {
    const STEPS: usize = 4;
    let params = Arc::new(vec![1.0f32]);

    // Pipelined-equivalent arm: stage → sync → stage, constant params.
    let cfg = golden_cfg(RolloutMode::Copris);
    let mut pip = Coordinator::new(
        spawn_pool(1, 1, cfg.train.seed, 4, 6, 200),
        cfg.clone(),
        MAX_SEQ,
    );
    pip.sync_weights(1, params.clone());
    let mut ds_p = Dataset::train(cfg.train.seed);
    let mut want = Vec::new();
    for version in 2..2 + STEPS as u64 {
        let out = pip.rollout_stage(&mut ds_p).unwrap();
        want.push(fingerprint(&out));
        pip.sync_weights(version, params.clone());
    }
    pip.shutdown();

    // Async arm at S = 0: one never-quiescing stream; after each taken
    // batch, a full staleness cut + sync + refill.
    let acfg = async_cfg(0);
    let mut asy = Coordinator::new(
        spawn_pool(1, 1, acfg.train.seed, 4, 6, 200),
        acfg.clone(),
        MAX_SEQ,
    );
    asy.sync_weights(1, params.clone());
    let mut ds_a = Dataset::train(acfg.train.seed);
    asy.begin_async(&mut ds_a).unwrap();
    let mut cut_total = 0usize;
    for (step, version) in (2..2 + STEPS as u64).enumerate() {
        while !asy
            .pump_async(&mut ds_a, Instant::now() + Duration::from_secs(60))
            .unwrap()
        {}
        let out = asy.take_async_batch().unwrap();
        assert_eq!(out.groups.len(), acfg.rollout.batch_prompts, "exact-B delivery");
        assert_eq!(
            fingerprint(&out),
            want[step],
            "async S=0 diverged from the pipelined stage sequence at batch {step}"
        );
        if step == 0 {
            // Batch-ready fired at B staged groups — the occupancy gauge
            // must have seen them.
            assert!(
                out.stats.staging_occupancy_peak >= acfg.rollout.batch_prompts,
                "{:?}",
                out.stats
            );
        }
        cut_total += out.stats.staleness_terminations;
        asy.prepare_sync(version).unwrap();
        asy.sync_weights(version, params.clone());
        asy.resume_refill(&mut ds_a).unwrap();
    }
    // Cut counts land in the window AFTER the take (stats travel with the
    // batch); with N' = 4 kept full, every S=0 sync cuts in-flight work.
    assert!(cut_total > 0, "S=0 syncs recorded no staleness terminations");
    asy.abort_stage().unwrap();
    asy.shutdown();
}

/// Bounded-staleness property: with `rollout.max_staleness = S`, no
/// harvested segment may span more than S syncs — every segment of every
/// trajectory satisfies `policy_version − dispatch_version ≤ S`, under
/// multi-slot timing races, long scripts spanning windows, *varying*
/// params (real weight updates), and the active (APRIL) cut policy.
#[test]
fn async_bounded_staleness_property() {
    for s in [0usize, 1, 2] {
        let mut cfg = async_cfg(s);
        cfg.rollout.active_termination = true;
        // Multi-slot + long scripts → work genuinely spans sync windows.
        let mut coord = Coordinator::new(spawn_pool(1, 4, 9, 15, 20, 200), cfg.clone(), MAX_SEQ);
        coord.sync_weights(1, Arc::new(vec![1.0f32]));
        let mut ds = Dataset::train(9);
        coord.begin_async(&mut ds).unwrap();
        let mut cuts = 0usize;
        for version in 2..6u64 {
            while !coord
                .pump_async(&mut ds, Instant::now() + Duration::from_secs(60))
                .unwrap()
            {}
            let out = coord.take_async_batch().unwrap();
            assert_eq!(out.groups.len(), cfg.rollout.batch_prompts);
            for grp in &out.groups {
                for t in &grp.done {
                    assert!(t.complete && t.invariant_ok());
                    for seg in &t.segments {
                        assert!(
                            seg.staleness() <= s as u64,
                            "segment spans {} syncs > bound {s}",
                            seg.staleness()
                        );
                    }
                }
            }
            cuts += out.stats.staleness_terminations + out.stats.active_terminations;
            coord.prepare_sync(version).unwrap();
            coord.sync_weights(version, Arc::new(vec![version as f32]));
            coord.resume_refill(&mut ds).unwrap();
        }
        if s == 0 {
            assert!(cuts > 0, "S=0 stream never cut anything");
        }
        coord.abort_stage().unwrap();
        coord.shutdown();
    }
}
