//! Counting-allocator proof of the zero-allocation decode hot path: once
//! slots are mid-generation (scratches sized, output vectors reserved at
//! admission), `Engine::step` over `MockBackend` must perform ZERO heap
//! allocations — softmax sampling, top-k/top-p filtering, logits delivery,
//! and busy/kv bookkeeping all run in reused storage.
//!
//! Single test fn on purpose: the counter is process-global, so scenarios
//! run sequentially inside it (libtest would otherwise interleave them).

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};

use copris::engine::{Engine, EngineEvent, MockBackend, SamplingParams, WorkItem};

struct CountingAlloc;

static COUNTING: AtomicBool = AtomicBool::new(false);
static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        if COUNTING.load(Ordering::Relaxed) {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
        }
        System.alloc(layout)
    }
    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        if COUNTING.load(Ordering::Relaxed) {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
        }
        System.alloc_zeroed(layout)
    }
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        if COUNTING.load(Ordering::Relaxed) {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
        }
        System.realloc(ptr, layout, new_size)
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static ALLOCATOR: CountingAlloc = CountingAlloc;

/// Drive `steps` steady-state decode steps and return the allocation count.
fn count_steady_state_allocs(sampling: SamplingParams, steps: usize) -> u64 {
    const SLOTS: usize = 4;
    const MAX_SEQ: usize = 192;
    let mut be = MockBackend::new(SLOTS, MAX_SEQ);
    // Long scripted outputs: no slot reaches EOS during the measured
    // window, so every step is pure decode (the steady state).
    be.min_len = 150;
    be.spread = 1;
    let mut eng = Engine::new(0, be, 0, 1);
    for i in 0..SLOTS as u64 {
        eng.submit(WorkItem {
            request_id: i,
            prompt: vec![1, i as i32 + 4, 9].into(),
            resume: vec![],
            max_total: MAX_SEQ,
            sampling,
            retain: None,
            prefix: None,
        })
        .unwrap();
    }
    // Warmup: admission (prefill + per-slot output reservation) and first
    // decode steps size every scratch — logits buffer, sampler workspace,
    // token/pos staging, events vec.
    let mut ev: Vec<EngineEvent> = Vec::with_capacity(64);
    for _ in 0..10 {
        eng.step(&mut ev).unwrap();
        ev.clear();
    }
    assert_eq!(eng.busy(), SLOTS, "warmup must leave all slots mid-generation");

    ALLOCS.store(0, Ordering::SeqCst);
    COUNTING.store(true, Ordering::SeqCst);
    for _ in 0..steps {
        eng.step(&mut ev).unwrap();
        ev.clear();
    }
    COUNTING.store(false, Ordering::SeqCst);
    assert_eq!(eng.busy(), SLOTS, "no slot may finish inside the window");
    ALLOCS.load(Ordering::SeqCst)
}

#[test]
fn steady_state_decode_steps_do_not_allocate() {
    // Softmax-only sampling (paper defaults) ...
    let n = count_steady_state_allocs(SamplingParams::default(), 100);
    assert_eq!(n, 0, "default-params decode steps allocated {n} times");
    // ... and the full top-k partial-selection + top-p nucleus path.
    let p = SamplingParams { temperature: 0.9, top_p: 0.9, top_k: 8 };
    let n = count_steady_state_allocs(p, 100);
    assert_eq!(n, 0, "top-k/top-p decode steps allocated {n} times");
}
