//! Determinism and statistical-fidelity gate for the open-loop load
//! generator (`copris::loadgen`).
//!
//! Three layers:
//! 1. **Replay**: a `(process, seed)` pair must regenerate a
//!    byte-identical arrival schedule, and a fixed [`SimConfig`] must
//!    replay a bit-identical [`SloReport`] — compared between two
//!    in-process runs, never against golden constants.
//! 2. **Cross-profile**: with `COPRIS_LOADGEN_TRACE=<path>` set, the
//!    canonical trace (schedules + sim reports rendered via `Debug`) is
//!    written on first run and compared on later runs. `scripts/ci.sh
//!    --slo` runs this test under the debug profile (writes) and then the
//!    release profile (compares) with one shared path, proving the
//!    generator is bit-identical across build profiles. Unset, the test
//!    is a no-op.
//! 3. **Fidelity**: the heavy-tailed length sampler's empirical quantiles
//!    and mean track the bounded-Pareto closed forms, and the tenant-mix
//!    class proportions converge to the configured share — so the
//!    deterministic schedules are also the *right* distribution.

use copris::loadgen::{
    run_sim, ArrivalGen, ArrivalProcess, BoundedPareto, SimConfig, TenantClass, TenantMix,
};
use copris::util::stats::percentile;
use copris::util::Rng;

fn processes() -> Vec<(&'static str, ArrivalProcess)> {
    vec![
        ("poisson-400", ArrivalProcess::Poisson { rate_rps: 400.0 }),
        ("poisson-2000", ArrivalProcess::Poisson { rate_rps: 2_000.0 }),
        (
            "bursty-400",
            ArrivalProcess::Bursty { rate_rps: 400.0, on_ticks: 20_000, off_ticks: 80_000 },
        ),
    ]
}

fn trace_sims() -> Vec<SimConfig> {
    vec![
        SimConfig { requests: 120, seed: 42, ..SimConfig::default() },
        SimConfig {
            engines: 1,
            slots: 2,
            queue_cap: 6,
            requests: 90,
            seed: 42,
            process: ArrivalProcess::Bursty {
                rate_rps: 2_500.0,
                on_ticks: 10_000,
                off_ticks: 30_000,
            },
            mix: TenantMix::default_mix(0.3),
            ..SimConfig::default()
        },
    ]
}

/// Canonical textual rendering of everything that must be bit-stable:
/// integer arrival ticks plus the `Debug` form of each sim report (f64
/// `Debug` is the shortest round-trip representation, so equal strings
/// mean equal bits).
fn canonical_trace() -> String {
    let mut s = String::new();
    for (name, p) in processes() {
        let ticks = ArrivalGen::new(p, 42).schedule(600);
        s.push_str(name);
        s.push(' ');
        for t in ticks {
            s.push_str(&t.to_string());
            s.push(',');
        }
        s.push('\n');
    }
    for cfg in trace_sims() {
        let r = run_sim(&cfg);
        assert!(r.completed_all, "trace sim must drain");
        s.push_str(&format!("{:?} rounds={} end={}\n", r.report, r.rounds, r.end_tick));
    }
    s
}

#[test]
fn arrival_schedules_replay_byte_identically() {
    for (name, p) in processes() {
        for seed in [0u64, 7, 0xDEAD_BEEF] {
            let a = ArrivalGen::new(p, seed).schedule(3_000);
            let b = ArrivalGen::new(p, seed).schedule(3_000);
            assert_eq!(a, b, "{name} seed {seed} must replay identically");
            for w in a.windows(2) {
                assert!(w[1] > w[0], "{name}: arrival ticks must strictly increase");
            }
        }
        let a = ArrivalGen::new(p, 1).schedule(500);
        let b = ArrivalGen::new(p, 2).schedule(500);
        assert_ne!(a, b, "{name}: different seeds must diverge");
    }
}

#[test]
fn sim_reports_replay_bit_identically() {
    for cfg in trace_sims() {
        let a = run_sim(&cfg);
        let b = run_sim(&cfg);
        assert_eq!(a.report, b.report, "same-seed sim reports must be bit-identical");
        assert_eq!(a.rounds, b.rounds);
        assert_eq!(a.end_tick, b.end_tick);
        assert_eq!(a.engine_preemptions, b.engine_preemptions);
    }
}

/// Cross-profile golden-file handshake (see module docs). First run with
/// the env var set writes the trace; later runs (e.g. the release build
/// in `ci.sh --slo`) must reproduce it byte-for-byte.
#[test]
fn cross_profile_trace_matches_golden_file() {
    let Ok(path) = std::env::var("COPRIS_LOADGEN_TRACE") else { return };
    let trace = canonical_trace();
    match std::fs::read_to_string(&path) {
        Ok(golden) => assert_eq!(
            golden, trace,
            "loadgen trace diverged from the golden file at {path} — the \
             generator is not bit-identical across build profiles/runs"
        ),
        Err(_) => std::fs::write(&path, &trace).expect("write loadgen golden trace"),
    }
}

#[test]
fn pareto_empirical_quantiles_track_analytic() {
    for &(lo, hi, alpha) in &[(8usize, 96usize, 1.2f64), (4, 24, 2.5), (8, 48, 1.8)] {
        let d = BoundedPareto::new(lo, hi, alpha);
        let mut rng = Rng::new(5);
        let xs: Vec<f64> = (0..20_000).map(|_| d.sample(&mut rng) as f64).collect();
        for q in [0.25, 0.5, 0.9] {
            let emp = percentile(&xs, q);
            let ana = d.quantile(q).round().clamp(lo as f64, hi as f64);
            let rel = (emp - ana).abs() / ana;
            assert!(
                rel < 0.12,
                "BP({lo},{hi},{alpha}) q{q}: empirical {emp} vs analytic {ana} (rel {rel:.3})"
            );
        }
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let rel = (mean - d.mean()).abs() / d.mean();
        assert!(
            rel < 0.10,
            "BP({lo},{hi},{alpha}) mean: empirical {mean} vs analytic {} (rel {rel:.3})",
            d.mean()
        );
    }
}

#[test]
fn tenant_mix_proportions_converge() {
    let mix = TenantMix::default_mix(0.3);
    let mut rng = Rng::new(17);
    let n = 4_000;
    let interactive =
        (0..n).filter(|_| mix.sample(&mut rng).class == TenantClass::Interactive).count();
    let share = interactive as f64 / n as f64;
    assert!(
        (share - 0.3).abs() < 0.03,
        "interactive share {share:.3} drifted from configured 0.3"
    );
}
