//! Local-vs-multi-process transport goldens for the router tier.
//!
//! THE correctness pin for the router/transport subsystem: a rollout run
//! over `transport = "tcp"` (engine-host processes behind the framed wire
//! protocol — here in-test threads serving real loopback sockets, which
//! exercises the identical codec/link code the subprocess mode runs) must
//! produce BIT-IDENTICAL greedy trajectory streams to the same run over
//! the in-process `local` transport. That holds by construction — hosts
//! spawn their engines at router-assigned POOL-GLOBAL ids with the
//! router's seed, so events cross the wire untranslated and the
//! coordinator cannot tell the transports apart — and these tests pin it.
//!
//! Comparison regimes mirror the proven-deterministic goldens:
//! 1 engine × 1 slot for the partial modes (single-file processing, see
//! `rollout_golden.rs` module docs), multi-engine/multi-slot for sync
//! (set-deterministic; `chaos_recovery.rs` relies on the same property).
//! Plus: drain/health, heartbeat death of a wedged host, and fleet
//! validation at connect.

use std::io::Read as _;
use std::net::TcpListener;
use std::thread::JoinHandle;

use copris::config::{Config, RolloutMode, TransportKind};
use copris::coordinator::{Coordinator, RolloutOutput};
use copris::engine::{EnginePool, MockBackend};
use copris::net::host::{serve, HostBackend, HostConfig};
use copris::net::wire::{self, WireMsg, PROTO_VERSION};
use copris::router::{ReplicaHealth, RouterPool};
use copris::tasks::Dataset;

const MAX_SEQ: usize = 96;

/// Mock-script knobs shared verbatim by both sides of a comparison.
#[derive(Clone, Copy)]
struct Knobs {
    slots: usize,
    min_len: usize,
    spread: usize,
    delay_us: u64,
}

/// Local-transport pool built EXACTLY like the hosts build theirs
/// (supervised, same engine/supervisor opts, raw `MockBackend`).
fn local_pool(cfg: &Config, engines: usize, k: Knobs) -> EnginePool {
    EnginePool::spawn_supervised(
        engines,
        k.slots,
        cfg.engine.engine_opts(),
        cfg.engine.supervisor_opts(),
        cfg.train.seed,
        move |_id| {
            Box::new(move || {
                let mut b = MockBackend::new(k.slots, MAX_SEQ);
                b.min_len = k.min_len;
                b.spread = k.spread;
                if k.delay_us > 0 {
                    b.decode_delay = Some(std::time::Duration::from_micros(k.delay_us));
                }
                Ok(b)
            })
        },
    )
    .unwrap()
}

/// Start one in-test engine-host serving a bound loopback listener on its
/// own thread (`once` — the thread exits when the router disconnects).
fn spawn_host(cfg: &Config, engines: usize, k: Knobs, crash_after: Option<u64>) -> Host {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let hc = HostConfig {
        engines,
        slots: k.slots,
        engine_opts: cfg.engine.engine_opts(),
        sup: cfg.engine.supervisor_opts(),
        backend: HostBackend::Mock {
            min_len: k.min_len,
            spread: k.spread,
            decode_delay_us: k.delay_us,
            max_seq: MAX_SEQ,
        },
        crash_after_events: crash_after,
        crash_exit: false,
    };
    let thread = std::thread::spawn(move || {
        let _ = serve(listener, hc, true);
    });
    Host { addr, thread }
}

struct Host {
    addr: String,
    thread: JoinHandle<()>,
}

/// Dial a fleet of already-listening hosts over the tcp transport.
fn connect_fleet(cfg: &mut Config, hosts: &[Host]) -> RouterPool {
    cfg.router.transport = TransportKind::Tcp;
    cfg.router.hosts = hosts.iter().map(|h| h.addr.clone()).collect::<Vec<_>>().join(",");
    RouterPool::connect(&cfg.router, cfg.train.seed).unwrap()
}

/// Canonical stage fingerprint (see `rollout_golden.rs`): groups sorted by
/// task prompt; per group the sorted multiset of (tokens, logprob bits).
type Fingerprint = Vec<(String, usize, Vec<(Vec<i32>, Vec<u32>)>)>;

fn fingerprint(out: &RolloutOutput) -> Fingerprint {
    let mut groups: Vec<_> = out
        .groups
        .iter()
        .map(|g| {
            let mut streams: Vec<(Vec<i32>, Vec<u32>)> = g
                .done
                .iter()
                .map(|t| {
                    (
                        t.tokens.clone(),
                        t.behavior_logprobs().iter().map(|l| l.to_bits()).collect(),
                    )
                })
                .collect();
            streams.sort();
            (g.task.prompt.clone(), g.target, streams)
        })
        .collect();
    groups.sort();
    groups
}

fn golden_cfg(mode: RolloutMode) -> Config {
    let mut cfg = Config::new("mock");
    cfg.rollout.mode = mode;
    cfg.rollout.batch_prompts = 3;
    cfg.rollout.group_size = 2;
    cfg.rollout.concurrency = 4;
    cfg.rollout.temperature = 0.0; // greedy → streams scripted, no RNG
    cfg.engine.retry_backoff_ms = 0;
    cfg.train.seed = 5;
    cfg
}

/// Run `stages` rollout stages and return per-stage fingerprints.
fn run_stages(coord: &mut Coordinator, seed: u64, stages: usize) -> Vec<Fingerprint> {
    let mut ds = Dataset::train(seed);
    (0..stages).map(|_| fingerprint(&coord.rollout_stage(&mut ds).unwrap())).collect()
}

/// THE acceptance pin, partial-mode arm: all three rollout modes over one
/// remote host (1 engine × 1 slot — the proven-deterministic regime) are
/// bit-identical to the local transport across three stages, including
/// partial buffering and resumption crossing the wire.
#[test]
fn tcp_single_host_matches_local_all_modes() {
    let k = Knobs { slots: 1, min_len: 4, spread: 6, delay_us: 200 };
    for mode in [RolloutMode::Sync, RolloutMode::NaivePartial, RolloutMode::Copris] {
        let mut cfg = golden_cfg(mode);
        cfg.engine.engines = 1;

        let mut local = Coordinator::new(local_pool(&cfg, 1, k), cfg.clone(), MAX_SEQ);
        let want = run_stages(&mut local, cfg.train.seed, 3);
        local.shutdown();

        let host = spawn_host(&cfg, 1, k, None);
        let pool = connect_fleet(&mut cfg, std::slice::from_ref(&host));
        let mut remote = Coordinator::new(pool, cfg.clone(), MAX_SEQ);
        assert_eq!(remote.pool.transport_name(), "tcp");
        assert_eq!(remote.pool.engines(), 1);
        let got = run_stages(&mut remote, cfg.train.seed, 3);
        remote.shutdown();
        host.thread.join().unwrap();

        assert_eq!(got, want, "tcp transport diverged from local in mode {mode:?}");
    }
}

/// THE acceptance pin, multi-host arm: a 2-host fleet (1 engine × 4 slots
/// each, global ids 0 and 1) runs the sync golden bit-identically to one
/// local 2-engine pool. The second host's engine id base is nonzero, so
/// this also pins the global-id assignment across the wire.
#[test]
fn tcp_two_hosts_match_local_sync_golden() {
    let k = Knobs { slots: 4, min_len: 3, spread: 8, delay_us: 100 };
    let mut cfg = golden_cfg(RolloutMode::Sync);
    cfg.engine.engines = 2;

    let mut local = Coordinator::new(local_pool(&cfg, 2, k), cfg.clone(), MAX_SEQ);
    let want = run_stages(&mut local, cfg.train.seed, 2);
    local.shutdown();

    let hosts = [spawn_host(&cfg, 1, k, None), spawn_host(&cfg, 1, k, None)];
    let pool = connect_fleet(&mut cfg, &hosts);
    assert_eq!(pool.engines(), 2);
    assert_eq!(pool.total_slots(), 8);
    assert_eq!(pool.link_alive(), vec![true, true]);
    let mut remote = Coordinator::new(pool, cfg.clone(), MAX_SEQ);
    let got = run_stages(&mut remote, cfg.train.seed, 2);
    remote.shutdown();
    for h in hosts {
        h.thread.join().unwrap();
    }

    assert_eq!(got, want, "2-host fleet diverged from local 2-engine pool");
}

/// Retained-KV affinity over the wire: a copris run with `retain_kv` must
/// keep its streams bit-identical to local AND actually hit the retained
/// fast path remotely (`StopGeneration{retain}` → `Flushed{retained}` →
/// affinity-routed `Assign{use_retained}` all crossing the socket).
#[test]
fn tcp_retained_resume_matches_local_and_hits() {
    let k = Knobs { slots: 1, min_len: 20, spread: 30, delay_us: 100 };
    let mut cfg = golden_cfg(RolloutMode::Copris);
    cfg.rollout.batch_prompts = 2;
    cfg.rollout.concurrency = 4;
    cfg.rollout.retain_kv = true;
    cfg.engine.engines = 1;
    cfg.train.seed = 7;

    let mut local = Coordinator::new(local_pool(&cfg, 1, k), cfg.clone(), MAX_SEQ);
    let mut ds = Dataset::train(cfg.train.seed);
    let mut want = Vec::new();
    let mut local_hits = 0usize;
    for _ in 0..3 {
        let out = local.rollout_stage(&mut ds).unwrap();
        local_hits += out.stats.retained_hits;
        want.push(fingerprint(&out));
    }
    local.shutdown();
    assert!(local_hits > 0, "workload must exercise retained resume locally");

    let host = spawn_host(&cfg, 1, k, None);
    let pool = connect_fleet(&mut cfg, std::slice::from_ref(&host));
    let mut remote = Coordinator::new(pool, cfg.clone(), MAX_SEQ);
    let mut ds = Dataset::train(cfg.train.seed);
    let mut got = Vec::new();
    let mut remote_hits = 0usize;
    for _ in 0..3 {
        let out = remote.rollout_stage(&mut ds).unwrap();
        remote_hits += out.stats.retained_hits;
        got.push(fingerprint(&out));
    }
    remote.shutdown();
    host.thread.join().unwrap();

    assert_eq!(got, want, "retained-resume streams diverged across transports");
    assert_eq!(remote_hits, local_hits, "retained fast path differs across transports");
}

/// Draining: a draining replica stops receiving new work but the stage
/// still delivers the exact fault-free trajectory set (streams are
/// engine-invariant); undraining restores it to rotation. One host with
/// TWO engines, so per-host engine fan-out is covered too.
#[test]
fn draining_replica_routes_around_and_restores() {
    let k = Knobs { slots: 2, min_len: 6, spread: 8, delay_us: 0 };
    let mut cfg = golden_cfg(RolloutMode::Sync);
    cfg.engine.engines = 2;

    let mut local = Coordinator::new(local_pool(&cfg, 2, k), cfg.clone(), MAX_SEQ);
    let want = run_stages(&mut local, cfg.train.seed, 1);
    local.shutdown();

    let host = spawn_host(&cfg, 2, k, None);
    let pool = connect_fleet(&mut cfg, std::slice::from_ref(&host));
    assert_eq!(pool.engines(), 2);
    let mut remote = Coordinator::new(pool, cfg.clone(), MAX_SEQ);

    assert!(remote.drain_engine(1), "draining a healthy replica must succeed");
    assert_eq!(
        remote.replica_health(),
        vec![ReplicaHealth::Healthy, ReplicaHealth::Draining]
    );
    let got = run_stages(&mut remote, cfg.train.seed, 1);
    assert_eq!(got, want, "drained run changed the delivered trajectory set");
    assert!(remote.undrain_engine(1), "undraining a live replica must succeed");
    assert_eq!(
        remote.replica_health(),
        vec![ReplicaHealth::Healthy, ReplicaHealth::Healthy]
    );
    remote.shutdown();
    host.thread.join().unwrap();
}

/// A wedged host — socket open, never answers pings, never emits events —
/// is declared dead by the HEARTBEAT (not a socket error), its replica
/// funnels into the standard `EngineFailed` recovery path, and the stage
/// completes on the surviving host with the fault-free trajectory set.
#[test]
fn heartbeat_declares_wedged_host_dead_and_stage_recovers() {
    let k = Knobs { slots: 2, min_len: 6, spread: 8, delay_us: 0 };
    let mut cfg = golden_cfg(RolloutMode::Sync);
    cfg.engine.engines = 2;

    let mut local = Coordinator::new(local_pool(&cfg, 2, k), cfg.clone(), MAX_SEQ);
    let want = run_stages(&mut local, cfg.train.seed, 1);
    local.shutdown();

    // Wedge: handshakes like a 1-engine host, then reads-and-discards
    // forever — no pongs, no events. Only the heartbeat can catch this.
    let wedge_listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let wedge_addr = wedge_listener.local_addr().unwrap().to_string();
    let wedge_slots = k.slots as u64;
    let wedge = std::thread::spawn(move || {
        let (mut s, _) = wedge_listener.accept().unwrap();
        let hello = wire::read_msg(&mut s).unwrap();
        assert!(matches!(hello, WireMsg::Hello { proto: PROTO_VERSION, .. }));
        wire::write_msg(
            &mut s,
            &WireMsg::HelloAck { proto: PROTO_VERSION, engines: 1, slots: wedge_slots },
        )
        .unwrap();
        let mut sink = [0u8; 4096];
        while matches!(s.read(&mut sink), Ok(n) if n > 0) {}
    });

    let real = spawn_host(&cfg, 1, k, None);
    cfg.router.transport = TransportKind::Tcp;
    cfg.router.hosts = format!("{},{}", real.addr, wedge_addr);
    cfg.router.heartbeat_ms = 50;
    cfg.router.heartbeat_misses = 2;
    let pool = RouterPool::connect(&cfg.router, cfg.train.seed).unwrap();
    assert_eq!(pool.engines(), 2);
    let mut remote = Coordinator::new(pool, cfg.clone(), MAX_SEQ);

    let mut ds = Dataset::train(cfg.train.seed);
    let out = remote.rollout_stage(&mut ds).unwrap();
    assert_eq!(fingerprint(&out), want[0], "recovery diverged from fault-free streams");
    assert!(out.stats.engine_failures >= 1, "{:?}", out.stats);
    assert!(out.stats.redispatched_trajectories > 0, "{:?}", out.stats);
    assert_eq!(remote.pool.link_alive(), vec![true, false]);
    assert_eq!(remote.replica_health()[1], ReplicaHealth::Dead);

    remote.shutdown();
    real.thread.join().unwrap();
    wedge.join().unwrap();
}

/// Connect-time fleet validation: a host advertising a different
/// slots-per-engine than the rest of the fleet is rejected outright (slot
/// accounting upstairs assumes uniformity).
#[test]
fn connect_rejects_mixed_slot_fleet() {
    let cfg = golden_cfg(RolloutMode::Sync);
    let a = spawn_host(&cfg, 1, Knobs { slots: 2, min_len: 4, spread: 6, delay_us: 0 }, None);
    let b = spawn_host(&cfg, 1, Knobs { slots: 3, min_len: 4, spread: 6, delay_us: 0 }, None);

    let mut rcfg = cfg.router.clone();
    rcfg.transport = TransportKind::Tcp;
    rcfg.hosts = format!("{},{}", a.addr, b.addr);
    let err = RouterPool::connect(&rcfg, cfg.train.seed).unwrap_err();
    assert!(format!("{err:#}").contains("uniform"), "{err:#}");

    // A failed bring-up severs the already-connected host A and drops the
    // half-shaken host B socket, so both `once` serve loops return.
    a.thread.join().unwrap();
    b.thread.join().unwrap();
}

/// `transport = "tcp"` with no hosts is a structured config error, not a
/// hang or a panic.
#[test]
fn connect_requires_hosts() {
    let mut rcfg = golden_cfg(RolloutMode::Sync).router.clone();
    rcfg.transport = TransportKind::Tcp;
    rcfg.hosts = String::new();
    let err = RouterPool::connect(&rcfg, 5).unwrap_err();
    assert!(format!("{err:#}").contains("router.hosts"), "{err:#}");
}
