//! Fig-1 style rollout diagnostics: run one synchronous stage and one
//! CoPRIS stage on real engines and print the long-tail length histogram
//! plus per-engine utilization traces.
//!
//!     cargo run --release --example rollout_trace -- --model small

use anyhow::Result;

use copris::cli::Args;
use copris::exp::fig1;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1), &[])?;
    let model = args.get("model").unwrap_or("small");
    let sft = args.get_usize("sft-steps", 60)?;
    let report = fig1::run(model, sft)?;
    println!("{}", fig1::render(&report));
    Ok(())
}
