//! End-to-end training driver (the EXPERIMENTS.md §E2E run): SFT warmup of
//! a transformer from scratch (loss curve) followed by a full CoPRIS RL
//! phase (reward curve), with per-step JSONL metrics.
//!
//!     cargo run --release --example train_full -- \
//!         --model small --sft-steps 300 --rl-steps 60 \
//!         --metrics runs/train_full.jsonl
//!
//! `--model large` / `--model xl` (after `make artifacts-all` /
//! `artifacts-xl`) scale the same driver up to the ~100M-param showcase.

use anyhow::Result;

use copris::cli::Args;
use copris::config::scaled_preset;
use copris::exp::RlSession;
use copris::trainer::MetricsLog;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1), &["verbose", "no-eval"])?;
    let model = args.get("model").unwrap_or("small").to_string();
    let sft_steps = args.get_usize("sft-steps", 200)?;
    let rl_steps = args.get_usize("rl-steps", 40)?;
    let seed = args.get_u64("seed", 7)?;

    let mut cfg = scaled_preset(&model);
    cfg.train.seed = seed;
    if let Some(c) = args.get("concurrency") {
        cfg.rollout.concurrency = c.parse()?;
    }
    println!(
        "== train_full: model={model} sft={sft_steps} rl={rl_steps} N'={} B={} G={} ==",
        cfg.rollout.concurrency, cfg.rollout.batch_prompts, cfg.rollout.group_size
    );

    let mut sess = RlSession::build(cfg)?;
    sess.verbose = true;
    if let Some(path) = args.get("metrics") {
        sess.log = MetricsLog::to_file(std::path::Path::new(path))?;
    }

    // Phase 1: supervised warmup — the "pretraining" loss curve.
    println!("-- phase 1: SFT ({sft_steps} steps) --");
    let t0 = std::time::Instant::now();
    let mut ds = copris::tasks::Dataset::sft(seed);
    let mut sft_curve = Vec::new();
    for s in 0..sft_steps {
        let mut sft = copris::trainer::SftTrainer::new(
            &mut sess.trainer.rt,
            &mut sess.trainer.state,
            (sess.trainer.cfg.train.lr * 3.0) as f32,
        );
        let m = sft.step(&mut ds, 2)?;
        sft_curve.push(m.loss);
        if s % 20 == 0 || s + 1 == sft_steps {
            println!("[sft {s:>4}] loss {:.4}", m.loss);
        }
    }
    println!("sft wall: {:.1}s", t0.elapsed().as_secs_f64());
    // Push warmed weights to the engines (version == optimizer step).
    let params = sess.trainer.params()?;
    let version = sess.trainer.step() as u64;
    sess.coord.sync_weights(version, params);

    if !args.flag("no-eval") {
        println!("-- basemodel eval --");
        let base = sess.evaluate(1)?;
        for s in &base.suites {
            println!("  {:<10} pass@1 {:.3}", s.name, s.pass_at_1);
        }
        println!("  {:<10} {:.3}", "AVERAGE", base.average());
    }

    // Phase 2: CoPRIS RL.
    println!("-- phase 2: CoPRIS RL ({rl_steps} steps) --");
    let summary = sess.train(rl_steps)?;
    println!(
        "rl wall {:.1}s  throughput {:.2} samples/s  util {:.0}%  preempt {}  replayed {}",
        summary.wall,
        summary.throughput,
        summary.mean_utilization * 100.0,
        summary.preemptions,
        summary.replayed_tokens
    );
    println!(
        "stage totals: rollout {:.1}s  cal_logprob {:.1}s  train {:.1}s  sync {:.1}s",
        summary.rollout_secs, summary.cal_logprob_secs, summary.train_secs, summary.sync_secs
    );

    // Loss / reward curves for the record.
    let show = |name: &str, xs: &[f64]| {
        let pts: Vec<String> = xs
            .iter()
            .enumerate()
            .step_by((xs.len() / 12).max(1))
            .map(|(i, v)| format!("{i}:{v:.3}"))
            .collect();
        println!("{name}: {}", pts.join("  "));
    };
    show("sft loss curve", &sft_curve);
    show("rl reward curve", &summary.reward_curve);
    show("rl entropy curve", &summary.entropy_curve);

    if !args.flag("no-eval") {
        println!("-- final eval --");
        let report = sess.evaluate(2)?;
        for s in &report.suites {
            println!("  {:<10} pass@1 {:.3}", s.name, s.pass_at_1);
        }
        println!("  {:<10} {:.3}", "AVERAGE", report.average());
    }
    sess.shutdown();
    Ok(())
}
