//! Head-to-head: veRL-style synchronous RL vs CoPRIS on identical settings
//! (a compact Table-1-shaped comparison with one command).
//!
//!     cargo run --release --example sync_vs_copris -- \
//!         --model small --rl-steps 12 --sft-steps 80

use anyhow::Result;

use copris::bench::render_table;
use copris::cli::Args;
use copris::config::RolloutMode;
use copris::exp::common::{arm_config, run_arm};

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1), &[])?;
    let model = args.get("model").unwrap_or("small").to_string();
    let rl_steps = args.get_usize("rl-steps", 12)?;
    let sft_steps = args.get_usize("sft-steps", 80)?;

    println!("== sync vs CoPRIS: model={model}, {rl_steps} RL steps each ==");
    println!("-- arm 1/2: veRL (sync) --");
    let sync = run_arm(arm_config(&model, RolloutMode::Sync, 7), sft_steps, rl_steps, true)?;
    println!("-- arm 2/2: CoPRIS --");
    let cop = run_arm(arm_config(&model, RolloutMode::Copris, 7), sft_steps, rl_steps, true)?;

    let headers = ["arm", "avg pass@1", "train s", "samples/s", "util %", "speedup"];
    let rows = vec![
        vec![
            "veRL (sync)".to_string(),
            format!("{:.3}", sync.average),
            format!("{:.1}", sync.summary.wall),
            format!("{:.2}", sync.summary.throughput),
            format!("{:.0}", sync.summary.mean_utilization * 100.0),
            "1.00x".to_string(),
        ],
        vec![
            "CoPRIS".to_string(),
            format!("{:.3}", cop.average),
            format!("{:.1}", cop.summary.wall),
            format!("{:.2}", cop.summary.throughput),
            format!("{:.0}", cop.summary.mean_utilization * 100.0),
            format!("{:.2}x", sync.summary.wall / cop.summary.wall.max(1e-9)),
        ],
    ];
    println!("{}", render_table(&headers, &rows));
    Ok(())
}
