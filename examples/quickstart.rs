//! Quickstart: the smallest end-to-end CoPRIS run.
//!
//!     make artifacts
//!     cargo run --release --example quickstart
//!
//! Builds the full stack on the `tiny` model: SFT warmup → a few CoPRIS
//! RL steps (concurrency-controlled partial rollout + cross-stage IS) →
//! eval on the five held-out suites.

use copris::config::scaled_preset;
use copris::exp::RlSession;

fn main() -> anyhow::Result<()> {
    let mut cfg = scaled_preset("tiny");
    cfg.rollout.batch_prompts = 4;
    cfg.rollout.group_size = 4;
    cfg.rollout.concurrency = 8;
    cfg.eval.prompts_per_suite = 8;
    cfg.eval.samples_per_prompt = 2;

    println!("building session (compiles artifacts/tiny via PJRT)...");
    let mut sess = RlSession::build(cfg)?;
    sess.verbose = true;

    println!("SFT warmup (the stand-in for a pretrained base model)...");
    let loss = sess.sft_warmup(40, 2)?;
    println!("warmup done, sft loss = {loss:.3}");

    println!("5 CoPRIS RL steps...");
    let summary = sess.train(5)?;
    println!(
        "reward curve: {:?}",
        summary.reward_curve.iter().map(|r| (r * 100.0).round() / 100.0).collect::<Vec<_>>()
    );
    println!(
        "throughput {:.2} samples/s, mean utilization {:.0}%",
        summary.throughput,
        summary.mean_utilization * 100.0
    );

    let report = sess.evaluate(2)?;
    for s in &report.suites {
        println!("  {:<10} pass@1 {:.3}", s.name, s.pass_at_1);
    }
    println!("  {:<10} {:.3}", "AVERAGE", report.average());
    sess.shutdown();
    Ok(())
}
